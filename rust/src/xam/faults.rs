//! Deterministic fault injection for the resistive XAM stack.
//!
//! The wear machinery models *how fast* cells age, but until this
//! module nothing ever actually failed. [`FaultPlane`] attaches to an
//! [`XamArray`](crate::xam::XamArray) and injects three seeded,
//! reproducible fault classes:
//!
//! - **stuck-at cells**: a per-cell hash of `(seed, salt, col, row)`
//!   marks a configurable per-mille of cells permanently stuck at 0 or
//!   1. A stuck cell only matters when a write wants the opposite
//!   value — detection is verify-after-write, and a conflicting column
//!   retires immediately (retries cannot help a stuck cell).
//! - **transient write failures**: each write attempt draws a
//!   stateless hash of `(seed, salt, col, write-sequence#)` against a
//!   probability knob. Failed attempts re-enter a bounded rewrite
//!   ladder; exhausting the ladder retires the column.
//! - **endurance exhaustion**: handled one layer up by
//!   [`WearLeveler`](crate::monarch::wear::WearLeveler) — cumulative
//!   per-superset writes crossing a threshold remap the superset to a
//!   spare, and when spares run out the superset degrades.
//!
//! The invariant the whole stack leans on: **a column either stores
//! exactly the intended word, or it is retired.** Stuck masks are
//! consulted only at checked-write verify points; the functional
//! mirror (`data[]` / bit planes) is never corrupted. Retired columns
//! are cleared to zero and masked out of every search path (bit-sliced
//! accumulators are AND'd with the live-column word; scalar sweeps
//! skip them), so a retired column can never produce a match — lookups
//! against lost words miss, they never lie.
//!
//! Everything is behind a zero-cost default: [`FaultConfig::default`]
//! disables every knob, no plane is attached, and a fault-free run is
//! bit-identical to a build without this module.

/// Knobs for the fault campaign. The default (all zeros) disables
/// injection entirely — no [`FaultPlane`] is attached and every device
/// behaves bit-identically to a fault-free build.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultConfig {
    /// Campaign seed; all fault draws derive from it deterministically.
    pub seed: u64,
    /// Stuck-at cell density, per mille of cells (0 = none).
    pub stuck_per_mille: u32,
    /// Transient write-failure probability, percent per attempt.
    pub transient_pct: f64,
    /// Rewrite-retry ladder depth after a transient failure.
    pub max_retries: u32,
    /// Cumulative per-superset write budget before endurance
    /// exhaustion (0 = endurance faults off).
    pub endurance: u64,
    /// Spare supersets available for endurance remapping.
    pub spare_supersets: u32,
}

impl FaultConfig {
    /// True when any fault class is armed.
    pub fn enabled(&self) -> bool {
        self.stuck_per_mille > 0
            || self.transient_pct > 0.0
            || self.endurance > 0
    }
}

/// Result of one checked (verify-after-write) column write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ColWrite {
    /// Write attempts issued (each one charges wear and energy).
    pub attempts: u32,
    /// The intended word is in the column (verified).
    pub stored: bool,
    /// This write pushed the column into retirement.
    pub retired_now: bool,
}

impl ColWrite {
    /// The fault-free fast path: one attempt, stored, no retirement.
    pub const CLEAN: ColWrite =
        ColWrite { attempts: 1, stored: true, retired_now: false };
}

/// Aggregated fault-pipeline counters across a device's arrays (and,
/// for the superset-level rows, its wear leveler) — the degradation
/// surface a driver reports instead of corrupting results.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultTotals {
    pub retired_columns: u64,
    pub lost_words: u64,
    pub transient_faults: u64,
    pub stuck_write_faults: u64,
    pub retry_writes: u64,
    pub degraded_sets: u64,
    pub spares_used: u64,
}

impl FaultTotals {
    /// Fold one array's plane counters in.
    pub fn absorb(&mut self, p: &FaultPlane) {
        self.retired_columns += p.retired_cols;
        self.lost_words += p.lost_words;
        self.transient_faults += p.transient_faults;
        self.stuck_write_faults += p.stuck_write_faults;
        self.retry_writes += p.retry_writes;
    }

    /// Fold another aggregate in (shard / region merges).
    pub fn merge(&mut self, o: &FaultTotals) {
        self.retired_columns += o.retired_columns;
        self.lost_words += o.lost_words;
        self.transient_faults += o.transient_faults;
        self.stuck_write_faults += o.stuck_write_faults;
        self.retry_writes += o.retry_writes;
        self.degraded_sets += o.degraded_sets;
        self.spares_used += o.spares_used;
    }

    pub fn any(&self) -> bool {
        *self != FaultTotals::default()
    }
}

/// SplitMix64 finalizer — a stateless avalanche mix so every fault
/// draw is a pure function of its coordinates (deterministic across
/// thread counts and ISA tiers by construction).
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

const STUCK_SALT: u64 = 0x5AC5_0FF5_E11D_0001;
const TRANSIENT_SALT: u64 = 0x7A25_1E27_FA17_0002;

/// Per-array fault state: stuck-cell masks, the retired-column bitmap,
/// and fault counters. One plane per [`XamArray`], distinguished by a
/// `salt` (the owner's array index) so sibling arrays draw independent
/// fault sets from one campaign seed.
#[derive(Clone, Debug)]
pub struct FaultPlane {
    seed: u64,
    salt: u64,
    transient_pct: f64,
    max_retries: u32,
    /// Per-column row-bit masks of cells stuck at 0 / stuck at 1.
    stuck0: Vec<u64>,
    stuck1: Vec<u64>,
    /// Retired-column bitmap, one bit per column.
    retired: Vec<u64>,
    any_retired: bool,
    // ---- counters (surfaced through device stats) ----
    pub retired_cols: u64,
    pub lost_words: u64,
    pub transient_faults: u64,
    pub stuck_write_faults: u64,
    pub retry_writes: u64,
}

impl FaultPlane {
    /// Build the plane for an array of `rows` x `cols` cells: the
    /// stuck-cell masks are drawn up front from per-cell hashes so the
    /// fault set is a pure function of `(config.seed, salt)`.
    pub fn new(cfg: &FaultConfig, salt: u64, rows: usize, cols: usize) -> Self {
        let mut stuck0 = vec![0u64; cols];
        let mut stuck1 = vec![0u64; cols];
        if cfg.stuck_per_mille > 0 {
            for (c, (s0, s1)) in
                stuck0.iter_mut().zip(stuck1.iter_mut()).enumerate()
            {
                for r in 0..rows {
                    let h = mix64(
                        cfg.seed
                            ^ STUCK_SALT
                            ^ salt.rotate_left(17)
                            ^ ((c as u64) << 8)
                            ^ r as u64,
                    );
                    if h % 1000 < cfg.stuck_per_mille as u64 {
                        if h & (1 << 60) != 0 {
                            *s1 |= 1 << r;
                        } else {
                            *s0 |= 1 << r;
                        }
                    }
                }
            }
        }
        Self {
            seed: cfg.seed,
            salt,
            transient_pct: cfg.transient_pct,
            max_retries: cfg.max_retries,
            stuck0,
            stuck1,
            retired: vec![0u64; cols.div_ceil(64)],
            any_retired: false,
            retired_cols: 0,
            lost_words: 0,
            transient_faults: 0,
            stuck_write_faults: 0,
            retry_writes: 0,
        }
    }

    /// Stuck-at-0 row mask of `col`.
    #[inline]
    pub fn stuck0(&self, col: usize) -> u64 {
        self.stuck0[col]
    }

    /// Stuck-at-1 row mask of `col`.
    #[inline]
    pub fn stuck1(&self, col: usize) -> u64 {
        self.stuck1[col]
    }

    /// What the array would hold after writing `word` to `col` —
    /// stuck-at cells override the driven value.
    #[inline]
    pub fn effective(&self, col: usize, word: u64) -> u64 {
        (word | self.stuck1[col]) & !self.stuck0[col]
    }

    /// Stateless transient-failure draw for write-sequence `seq` of
    /// `col`. Each retry attempt advances `seq` (the array's per-column
    /// write counter), so redraws are independent yet reproducible.
    #[inline]
    pub fn transient_hit(&self, col: usize, seq: u64) -> bool {
        if self.transient_pct <= 0.0 {
            return false;
        }
        let h = mix64(
            self.seed
                ^ TRANSIENT_SALT
                ^ self.salt.rotate_left(29)
                ^ ((col as u64) << 20)
                ^ seq,
        );
        let draw = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        draw * 100.0 < self.transient_pct
    }

    /// Rewrite-retry ladder depth.
    #[inline]
    pub fn max_retries(&self) -> u32 {
        self.max_retries
    }

    #[inline]
    pub fn is_retired(&self, col: usize) -> bool {
        self.retired[col / 64] & (1 << (col % 64)) != 0
    }

    /// Any column retired yet? Gates the search-path masking so a
    /// plane with no retirements costs nothing on the sweep.
    #[inline]
    pub fn any_retired(&self) -> bool {
        self.any_retired
    }

    /// Live-column mask for the bitmap word covering columns
    /// `[64w, 64w+64)`: bit set = column still in service.
    #[inline]
    pub fn live_word(&self, w: usize) -> u64 {
        !self.retired[w]
    }

    /// Mark `col` retired. The caller clears the column's functional
    /// state; `lost` says a nonzero intended word could not be stored.
    pub fn retire(&mut self, col: usize, lost: bool) {
        debug_assert!(!self.is_retired(col));
        self.retired[col / 64] |= 1 << (col % 64);
        self.any_retired = true;
        self.retired_cols += 1;
        if lost {
            self.lost_words += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_disabled() {
        assert!(!FaultConfig::default().enabled());
        let armed = FaultConfig { transient_pct: 0.5, ..Default::default() };
        assert!(armed.enabled());
    }

    #[test]
    fn stuck_masks_are_deterministic_and_salted() {
        let cfg = FaultConfig {
            seed: 99,
            stuck_per_mille: 50,
            ..Default::default()
        };
        let a = FaultPlane::new(&cfg, 7, 64, 512);
        let b = FaultPlane::new(&cfg, 7, 64, 512);
        let c = FaultPlane::new(&cfg, 8, 64, 512);
        assert_eq!(a.stuck0, b.stuck0);
        assert_eq!(a.stuck1, b.stuck1);
        assert_ne!(
            (a.stuck0, a.stuck1),
            (c.stuck0.clone(), c.stuck1.clone()),
            "different salts must draw different fault sets"
        );
        // no cell is stuck both ways
        for (s0, s1) in c.stuck0.iter().zip(c.stuck1.iter()) {
            assert_eq!(s0 & s1, 0);
        }
    }

    #[test]
    fn stuck_density_tracks_knob() {
        let cfg = FaultConfig {
            seed: 3,
            stuck_per_mille: 100, // 10%
            ..Default::default()
        };
        let p = FaultPlane::new(&cfg, 0, 64, 512);
        let stuck: u32 = p
            .stuck0
            .iter()
            .zip(p.stuck1.iter())
            .map(|(a, b)| (a | b).count_ones())
            .sum();
        let frac = stuck as f64 / (64.0 * 512.0);
        assert!((0.07..0.13).contains(&frac), "stuck fraction {frac}");
    }

    #[test]
    fn transient_draws_are_stateless_and_rate_accurate() {
        let cfg = FaultConfig {
            seed: 11,
            transient_pct: 5.0,
            max_retries: 2,
            ..Default::default()
        };
        let p = FaultPlane::new(&cfg, 1, 64, 512);
        let mut hits = 0u32;
        for seq in 0..20_000u64 {
            assert_eq!(p.transient_hit(3, seq), p.transient_hit(3, seq));
            if p.transient_hit(3, seq) {
                hits += 1;
            }
        }
        let rate = hits as f64 / 20_000.0;
        assert!((0.03..0.07).contains(&rate), "transient rate {rate}");
    }

    #[test]
    fn retire_sets_bitmap_and_counters() {
        let cfg =
            FaultConfig { seed: 1, transient_pct: 1.0, ..Default::default() };
        let mut p = FaultPlane::new(&cfg, 0, 16, 128);
        assert!(!p.any_retired());
        p.retire(70, true);
        assert!(p.is_retired(70));
        assert!(!p.is_retired(69));
        assert!(p.any_retired());
        assert_eq!(p.retired_cols, 1);
        assert_eq!(p.lost_words, 1);
        assert_eq!(p.live_word(1) & (1 << 6), 0);
        assert_eq!(p.live_word(0), !0);
    }
}
