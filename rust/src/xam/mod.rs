//! XAM — the reconfigurable RAM/CAM resistive crosspoint substrate
//! (paper §4-§6): arrays, diagonal supersets, and banks with
//! toggle-based sensing/port control.

pub mod array;
pub mod bank;
pub mod faults;
pub mod simd;
pub mod superset;

pub use array::{SearchOutcome, SearchScratch, XamArray};
pub use faults::{ColWrite, FaultConfig, FaultPlane};
pub use simd::Isa;
pub use bank::{Bank, SenseMode};
pub use superset::{PortMode, Superset};
