//! Explicit-SIMD kernels for the bit-sliced plane sweep.
//!
//! The whole search engine reduces to one inner operation: AND a
//! contiguous run of accumulator words with a plane's words (or their
//! complement) and learn whether anything is still alive. That kernel
//! is lifted here and widened to 128-bit (SSE2) and 256-bit (AVX2)
//! strides behind a runtime-detected [`Isa`] tier. Every tier computes
//! the exact same words — the operation is pure bitwise AND/NOT — so
//! tier choice is a host-speed decision with no modeled observables
//! attached, and the scalar loop stays as the portable fallback for
//! non-x86 targets.
//!
//! Tier selection happens once per process via [`Isa::active`]
//! (`is_x86_feature_detected!` behind a `cfg(target_arch)` shim) and
//! can be pinned with `MONARCH_FORCE_ISA={scalar,sse2,avx2}` so every
//! tier is testable on any machine; forcing a tier the host cannot run
//! clamps down to the best supported one with a notice.

use std::fmt;
use std::sync::OnceLock;

/// Instruction-set tier for the plane-sweep kernel. Ordered: a tier
/// compares greater than every tier it strictly extends, so clamping
/// a request against hardware support is just `min`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Isa {
    /// Portable `u64` loop; always available.
    Scalar,
    /// 128-bit strides (`__m128i`), baseline on x86_64.
    Sse2,
    /// 256-bit strides (`__m256i`).
    Avx2,
}

impl Isa {
    /// Best tier the host CPU can actually execute.
    pub fn detect() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if std::is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
            if std::is_x86_feature_detected!("sse2") {
                return Isa::Sse2;
            }
        }
        Isa::Scalar
    }

    /// Process-wide tier: `MONARCH_FORCE_ISA` when set (clamped to
    /// hardware support with a stderr notice), hardware best
    /// otherwise. Resolved once and cached.
    pub fn active() -> Isa {
        static ACTIVE: OnceLock<Isa> = OnceLock::new();
        *ACTIVE.get_or_init(|| {
            let hw = Isa::detect();
            let Ok(raw) = std::env::var("MONARCH_FORCE_ISA") else {
                return hw;
            };
            let raw = raw.trim();
            if raw.is_empty() {
                // empty = unset: lets CI matrices pass "" on the
                // unforced leg without a spurious notice
                return hw;
            }
            match Isa::parse(raw) {
                Some(want) if want <= hw => want,
                Some(want) => {
                    eprintln!(
                        "MONARCH_FORCE_ISA={raw}: {want} not supported \
                         on this host, clamping to {hw}"
                    );
                    hw
                }
                None => {
                    eprintln!(
                        "MONARCH_FORCE_ISA={raw}: unknown tier (want \
                         scalar|sse2|avx2); using {hw}"
                    );
                    hw
                }
            }
        })
    }

    pub fn parse(s: &str) -> Option<Isa> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "sse2" => Some(Isa::Sse2),
            "avx2" => Some(Isa::Avx2),
            _ => None,
        }
    }

    /// This tier, lowered to the best one the host supports.
    pub fn clamped(self) -> Isa {
        self.min(Isa::detect())
    }

    /// Can the host execute this tier?
    pub fn supported(self) -> bool {
        self <= Isa::detect()
    }

    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
        }
    }

    /// Every tier the host can execute, worst to best — the iteration
    /// set for per-tier differential tests and bench rows.
    pub fn supported_tiers() -> Vec<Isa> {
        [Isa::Scalar, Isa::Sse2, Isa::Avx2]
            .into_iter()
            .filter(|t| t.supported())
            .collect()
    }
}

impl fmt::Display for Isa {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The plane-sweep kernel: `acc[i] &= plane[i]` (or `&= !plane[i]`
/// when `invert`), returning the OR of the resulting words so callers
/// can test "anything still alive?" without a second pass. All tiers
/// are bit-identical by construction; `acc` and `plane` must be the
/// same length.
#[inline]
pub fn and_plane(isa: Isa, acc: &mut [u64], plane: &[u64], invert: bool) -> u64 {
    debug_assert_eq!(acc.len(), plane.len());
    match isa {
        Isa::Scalar => and_plane_scalar(acc, plane, invert),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: tiers above Scalar are only ever constructed after a
        // successful runtime feature check (`detect`/`clamped`).
        Isa::Sse2 => unsafe { and_plane_sse2(acc, plane, invert) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { and_plane_avx2(acc, plane, invert) },
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Sse2 | Isa::Avx2 => and_plane_scalar(acc, plane, invert),
    }
}

fn and_plane_scalar(acc: &mut [u64], plane: &[u64], invert: bool) -> u64 {
    let mut any = 0u64;
    if invert {
        for (a, &p) in acc.iter_mut().zip(plane) {
            *a &= !p;
            any |= *a;
        }
    } else {
        for (a, &p) in acc.iter_mut().zip(plane) {
            *a &= p;
            any |= *a;
        }
    }
    any
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn and_plane_sse2(acc: &mut [u64], plane: &[u64], invert: bool) -> u64 {
    use std::arch::x86_64::*;
    let lanes = acc.len() & !1;
    let flip = if invert {
        _mm_set1_epi64x(-1)
    } else {
        _mm_setzero_si128()
    };
    let mut anyv = _mm_setzero_si128();
    let mut i = 0;
    while i < lanes {
        let ap = acc.as_mut_ptr().add(i) as *mut __m128i;
        let pp = plane.as_ptr().add(i) as *const __m128i;
        let v = _mm_and_si128(
            _mm_loadu_si128(ap as *const __m128i),
            _mm_xor_si128(_mm_loadu_si128(pp), flip),
        );
        _mm_storeu_si128(ap, v);
        anyv = _mm_or_si128(anyv, v);
        i += 2;
    }
    let hi = _mm_unpackhi_epi64(anyv, anyv);
    let mut any = (_mm_cvtsi128_si64(anyv) | _mm_cvtsi128_si64(hi)) as u64;
    any |= and_plane_scalar(&mut acc[lanes..], &plane[lanes..], invert);
    any
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn and_plane_avx2(acc: &mut [u64], plane: &[u64], invert: bool) -> u64 {
    use std::arch::x86_64::*;
    let lanes = acc.len() & !3;
    let flip = if invert {
        _mm256_set1_epi64x(-1)
    } else {
        _mm256_setzero_si256()
    };
    let mut anyv = _mm256_setzero_si256();
    let mut i = 0;
    while i < lanes {
        let ap = acc.as_mut_ptr().add(i) as *mut __m256i;
        let pp = plane.as_ptr().add(i) as *const __m256i;
        let v = _mm256_and_si256(
            _mm256_loadu_si256(ap as *const __m256i),
            _mm256_xor_si256(_mm256_loadu_si256(pp), flip),
        );
        _mm256_storeu_si256(ap, v);
        anyv = _mm256_or_si256(anyv, v);
        i += 4;
    }
    let fold = _mm_or_si128(
        _mm256_castsi256_si128(anyv),
        _mm256_extracti128_si256(anyv, 1),
    );
    let hi = _mm_unpackhi_epi64(fold, fold);
    let mut any = (_mm_cvtsi128_si64(fold) | _mm_cvtsi128_si64(hi)) as u64;
    any |= and_plane_scalar(&mut acc[lanes..], &plane[lanes..], invert);
    any
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    #[test]
    fn tier_order_and_clamp() {
        assert!(Isa::Scalar < Isa::Sse2);
        assert!(Isa::Sse2 < Isa::Avx2);
        assert_eq!(Isa::Scalar.clamped(), Isa::Scalar);
        assert!(Isa::Avx2.clamped() <= Isa::detect());
        assert!(Isa::Scalar.supported());
        let tiers = Isa::supported_tiers();
        assert_eq!(tiers[0], Isa::Scalar);
        assert!(tiers.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn parse_is_case_insensitive_and_total() {
        assert_eq!(Isa::parse("scalar"), Some(Isa::Scalar));
        assert_eq!(Isa::parse("SSE2"), Some(Isa::Sse2));
        assert_eq!(Isa::parse("Avx2"), Some(Isa::Avx2));
        assert_eq!(Isa::parse("neon"), None);
        for t in Isa::supported_tiers() {
            assert_eq!(Isa::parse(t.name()), Some(t));
        }
    }

    #[test]
    fn every_supported_tier_matches_scalar_bit_for_bit() {
        let mut rng = 0x9E37_79B9_7F4A_7C15u64;
        for len in 0..=19usize {
            for trial in 0..16 {
                let plane: Vec<u64> =
                    (0..len).map(|_| xorshift(&mut rng)).collect();
                let base: Vec<u64> = (0..len)
                    .map(|_| {
                        // mix sparse and dense accumulators so the
                        // early-dead and still-alive cases both occur
                        if trial % 3 == 0 {
                            xorshift(&mut rng) & xorshift(&mut rng)
                        } else {
                            xorshift(&mut rng)
                        }
                    })
                    .collect();
                for invert in [false, true] {
                    let mut want = base.clone();
                    let want_any =
                        and_plane(Isa::Scalar, &mut want, &plane, invert);
                    assert_eq!(
                        want_any,
                        want.iter().fold(0, |o, &w| o | w),
                        "scalar any must be the OR of the result"
                    );
                    for tier in Isa::supported_tiers() {
                        let mut got = base.clone();
                        let got_any =
                            and_plane(tier, &mut got, &plane, invert);
                        assert_eq!(
                            got, want,
                            "{tier} words diverge (len={len} invert={invert})"
                        );
                        assert_eq!(
                            got_any, want_any,
                            "{tier} any diverges (len={len} invert={invert})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn odd_tails_hit_the_scalar_remainder() {
        // lengths chosen to exercise every lane remainder of the
        // 4-wide AVX2 and 2-wide SSE2 strides
        let plane: Vec<u64> = (0..7).map(|i| !0u64 << i).collect();
        for cut in 0..=plane.len() {
            let mut want = vec![!0u64; cut];
            let w = and_plane(Isa::Scalar, &mut want, &plane[..cut], true);
            for tier in Isa::supported_tiers() {
                let mut got = vec![!0u64; cut];
                let g = and_plane(tier, &mut got, &plane[..cut], true);
                assert_eq!((got, g), (want.clone(), w), "{tier} len={cut}");
            }
        }
    }
}
