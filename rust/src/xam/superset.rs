//! Superset organization (paper §6.1): XAM arrays grouped under shared
//! H-trees with *diagonal set arrangement* and a toggle-based port
//! selector.
//!
//! In an 8x8 superset the subarray at grid position (i, j) belongs to
//! set `k = (j - i) mod 8`; an access to set k selects the 8 subarrays
//! on that diagonal, and the port selector (a mode latch + 3-to-8
//! decoder) routes either the column ports (ColumnIn) or the row ports
//! (RowIn) to them. We model each *set* as one logical `XamArray`
//! (64 rows x 512 columns = the 8 diagonal 64x64 subarrays
//! concatenated column-wise) and keep the diagonal decode explicit for
//! fidelity tests.

use crate::xam::array::{SearchOutcome, XamArray};

/// Port-selector mode (§6.2 Activating a Superset): an `activate`
/// toggles between column and row access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PortMode {
    /// Data enters through column drivers (column writes; CAM data
    /// population; cache-tag partial updates via the mask register).
    ColumnIn,
    /// Data enters through row drivers (row writes in RAM mode;
    /// key/mask register writes in CAM mode).
    RowIn,
}

/// Diagonal decode: subarray (i, j) of the g x g grid belongs to set
/// `(j + g - i) % g`.
#[inline]
pub fn diagonal_set(grid: usize, i: usize, j: usize) -> usize {
    (j + grid - i) % grid
}

/// Subarrays selected for set `k`: one per grid row, at column
/// `(i + k) % g`.
pub fn diagonal_select(grid: usize, k: usize) -> Vec<(usize, usize)> {
    (0..grid).map(|i| (i, (i + k) % grid)).collect()
}

/// A superset: `sets` logical XAM sets sharing data/key/mask buffers
/// and one port selector.
#[derive(Clone, Debug)]
pub struct Superset {
    sets: Vec<XamArray>,
    /// Key/mask registers shared by all sets of the superset (§7):
    /// refreshed from the vault controller before a search when stale.
    pub key_reg: u64,
    pub mask_reg: u64,
    /// Monotonic version of the key/mask held here; the controller
    /// compares against its global registers to skip redundant updates.
    pub keymask_version: u64,
    pub mode: PortMode,
    grid: usize,
}

impl Superset {
    pub fn new(sets: usize, rows: usize, cols: usize) -> Self {
        Self {
            sets: (0..sets).map(|_| XamArray::new(rows, cols)).collect(),
            key_reg: 0,
            mask_reg: 0,
            keymask_version: 0,
            mode: PortMode::RowIn,
            grid: sets,
        }
    }

    pub fn num_sets(&self) -> usize {
        self.sets.len()
    }

    pub fn set(&self, k: usize) -> &XamArray {
        &self.sets[k]
    }

    pub fn set_mut(&mut self, k: usize) -> &mut XamArray {
        &mut self.sets[k]
    }

    /// Toggle the port selector (the `activate` command, §6.2).
    pub fn toggle_mode(&mut self) {
        self.mode = match self.mode {
            PortMode::ColumnIn => PortMode::RowIn,
            PortMode::RowIn => PortMode::ColumnIn,
        };
    }

    /// Latch new key/mask values (RowIn CAM; odd row address = mask,
    /// even = key, §6.2 Fine-grained XAM Access).
    pub fn load_keymask(&mut self, key: u64, mask: u64, version: u64) {
        self.key_reg = key;
        self.mask_reg = mask;
        self.keymask_version = version;
    }

    /// Search set `k` with the latched key/mask.
    pub fn search_set(&self, k: usize) -> SearchOutcome {
        self.sets[k].search(self.key_reg, self.mask_reg)
    }

    /// Fast path: first match only.
    pub fn search_set_first(&self, k: usize) -> Option<usize> {
        self.sets[k].search_first(self.key_reg, self.mask_reg)
    }

    /// Total write events across all sets (wear-leveling input).
    pub fn total_writes(&self) -> u64 {
        self.sets.iter().map(|s| s.total_writes()).sum()
    }

    /// Worst-case per-cell writes across sets.
    pub fn max_cell_writes(&self) -> u64 {
        self.sets.iter().map(|s| s.max_cell_writes()).max().unwrap_or(0)
    }

    pub fn reset_wear(&mut self) {
        self.sets.iter_mut().for_each(|s| s.reset_wear());
    }

    /// The subarray grid coordinates an access to set `k` selects.
    pub fn selected_subarrays(&self, k: usize) -> Vec<(usize, usize)> {
        diagonal_select(self.grid, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_mapping_is_a_partition() {
        // every subarray belongs to exactly one set, every set gets
        // exactly `grid` subarrays, one per row and one per column
        let g = 8;
        let mut per_set = vec![0usize; g];
        for i in 0..g {
            for j in 0..g {
                per_set[diagonal_set(g, i, j)] += 1;
            }
        }
        assert!(per_set.iter().all(|&c| c == g));
        for k in 0..g {
            let sel = diagonal_select(g, k);
            assert_eq!(sel.len(), g);
            // selection agrees with the membership function
            for &(i, j) in &sel {
                assert_eq!(diagonal_set(g, i, j), k);
            }
            // one subarray per row and per column (H-tree conflict-free)
            let mut rows: Vec<_> = sel.iter().map(|&(i, _)| i).collect();
            let mut cols: Vec<_> = sel.iter().map(|&(_, j)| j).collect();
            rows.sort_unstable();
            cols.sort_unstable();
            assert_eq!(rows, (0..g).collect::<Vec<_>>());
            assert_eq!(cols, (0..g).collect::<Vec<_>>());
        }
    }

    #[test]
    fn mode_toggles() {
        let mut ss = Superset::new(8, 64, 512);
        assert_eq!(ss.mode, PortMode::RowIn);
        ss.toggle_mode();
        assert_eq!(ss.mode, PortMode::ColumnIn);
        ss.toggle_mode();
        assert_eq!(ss.mode, PortMode::RowIn);
    }

    #[test]
    fn keymask_shared_across_sets() {
        let mut ss = Superset::new(8, 64, 64);
        ss.set_mut(2).write_col(10, 0xABCD);
        ss.set_mut(5).write_col(3, 0xABCD);
        ss.load_keymask(0xABCD, !0, 1);
        assert_eq!(ss.search_set_first(2), Some(10));
        assert_eq!(ss.search_set_first(5), Some(3));
        assert_eq!(ss.search_set_first(0), None);
        assert_eq!(ss.keymask_version, 1);
    }

    #[test]
    fn wear_aggregates_over_sets() {
        let mut ss = Superset::new(4, 64, 16);
        ss.set_mut(0).write_col(0, 1);
        ss.set_mut(3).write_col(1, 2);
        ss.set_mut(3).write_col(1, 3);
        assert_eq!(ss.total_writes(), 3);
        assert_eq!(ss.max_cell_writes(), 2);
        ss.reset_wear();
        assert_eq!(ss.total_writes(), 0);
    }
}
