//! Differential tests for the `device` trait redesign.
//!
//! The batched associative ops (`search_many`, `lookup_many`) promise
//! to be *sequential-equivalent*: same completion cycles, same hits,
//! same energy, same controller stats as issuing the scalar calls one
//! by one — only the functional match evaluation is hoisted into one
//! batch. These properties pin that promise on the pure-rust fallback
//! path (no artifacts needed), and the report-level tests pin that
//! trait-dispatch + batching leaves `SimReport`/`HashReport`
//! bit-identical across construction paths and batching modes.

use monarch::config::{InPackageKind, MonarchGeom, SystemConfig};
use monarch::device::{
    assoc, AssocDevice, AssocSpec, CamLookup, DeviceBuilder, MonarchAssoc,
    SearchHit, SearchOp, ShardedAssoc,
};
use monarch::mem::dram_cache::TechCache;
use monarch::prop_assert;
use monarch::sim::System;
use monarch::util::prop::{check, Gen};
use monarch::coordinator::{self, Budget};
use monarch::service::{run_service, ServiceConfig};
use monarch::xam::{FaultConfig, Isa};
use monarch::workloads::hashing::{
    run_ycsb, run_ycsb_adaptive, ReconfigPolicy, YcsbConfig,
};
use monarch::workloads::stringmatch::{run_string_match, StringMatchConfig};
use monarch::workloads::SyntheticStream;

fn small_geom() -> MonarchGeom {
    MonarchGeom {
        vaults: 4,
        banks_per_vault: 8,
        supersets_per_bank: 8,
        sets_per_superset: 8,
        rows_per_set: 64,
        cols_per_set: 512,
        layers: 1,
    }
}

/// Two identically-populated Monarch assoc devices.
fn twin_devices(g: &mut Gen, cam_sets: usize) -> (MonarchAssoc, MonarchAssoc) {
    let mut a = MonarchAssoc::new(small_geom(), cam_sets);
    let mut b = MonarchAssoc::new(small_geom(), cam_sets);
    let writes = 8 + g.int(64);
    for _ in 0..writes {
        let set = g.int(cam_sets);
        let col = g.int(512);
        let word = g.u64() | 1;
        let _ = a.cam_write(set, col, word, 0);
        let _ = b.cam_write(set, col, word, 0);
    }
    (a, b)
}

/// The scalar reference: the documented semantics of `search_many`,
/// spelled out with per-op `write_key`/`write_mask`/`search` calls
/// (this is also what the trait's provided default does).
fn sequential_search_many(
    dev: &mut MonarchAssoc,
    ops: &[SearchOp],
) -> Vec<SearchHit> {
    ops.iter()
        .map(|op| {
            let ka = dev.write_key(op.key, op.at);
            let ma = dev.write_mask(op.mask, ka.done_at);
            let (a, hit) = dev.search(op.set, ma.done_at);
            SearchHit {
                done_at: a.done_at,
                col: hit,
                energy_nj: ka.energy_nj + ma.energy_nj + a.energy_nj,
            }
        })
        .collect()
}

fn same_state(a: &MonarchAssoc, b: &MonarchAssoc) -> Result<(), String> {
    let (fa, fb) = (a.flat(), b.flat());
    if fa.keymask() != fb.keymask() {
        return Err(format!(
            "registers diverged: {:?} vs {:?}",
            fa.keymask(),
            fb.keymask()
        ));
    }
    let sa: Vec<_> = fa.stats.iter().collect();
    let sb: Vec<_> = fb.stats.iter().collect();
    if sa != sb {
        return Err(format!("stats diverged: {sa:?} vs {sb:?}"));
    }
    if fa.energy_nj != fb.energy_nj {
        return Err(format!(
            "internal energy diverged: {} vs {}",
            fa.energy_nj, fb.energy_nj
        ));
    }
    Ok(())
}

#[test]
fn prop_search_many_equals_sequential_searches() {
    check("search_many_vs_sequential", 40, |g: &mut Gen| {
        let cam_sets = 2 + g.int(14);
        let (mut batched, mut scalar) = twin_devices(g, cam_sets);
        // a small key pool so repeated keys exercise the register
        // dedup and match-register latch paths
        let pool = g.vec_u64(1 + g.int(4));
        // plant one pool key so hits (and the match-register latch on
        // repeated hits) occur
        let (pset, pcol) = (g.int(cam_sets), g.int(512));
        let _ = batched.cam_write(pset, pcol, pool[0], 0);
        let _ = scalar.cam_write(pset, pcol, pool[0], 0);
        let n_ops = 1 + g.int(24);
        let mut ops = Vec::with_capacity(n_ops);
        let mut at = 1000u64;
        for _ in 0..n_ops {
            at += g.u64() % 500;
            ops.push(SearchOp {
                set: g.int(cam_sets),
                key: pool[g.int(pool.len()).min(pool.len() - 1)],
                mask: if g.int(3) == 0 { 0xFFFF } else { !0 },
                at,
            });
        }
        let got = batched.search_many(&ops);
        let want = sequential_search_many(&mut scalar, &ops);
        prop_assert!(got == want, "results diverged: {got:?} vs {want:?}");
        same_state(&batched, &scalar)
    });
}

#[test]
fn prop_lookup_many_equals_scalar_sequence() {
    check("lookup_many_vs_scalar", 30, |g: &mut Gen| {
        let cam_sets = 2 + g.int(14);
        let (mut batched, mut scalar) = twin_devices(g, cam_sets);
        let n = 1 + g.int(12);
        let mut lookups = Vec::with_capacity(n);
        let mut at = 500u64;
        for _ in 0..n {
            at += g.u64() % 300;
            let set0 = g.int(cam_sets);
            let set1 =
                if g.int(2) == 0 { set0 } else { (set0 + 1) % cam_sets };
            lookups.push(CamLookup {
                key: g.u64() | 1,
                mask: !0,
                set0,
                set1,
                value_block: g.u64() % 4096,
                fetch_value_on_miss: g.int(3) == 0,
                at,
            });
        }
        let got = batched.lookup_many(&lookups);
        // scalar reference: the trait's provided default, spelled out
        let want: Vec<_> = lookups
            .iter()
            .map(|l| {
                let ka = scalar.write_key(l.key, l.at);
                let ma = scalar.write_mask(l.mask, ka.done_at);
                let (a, mut hit) = scalar.search(l.set0, ma.done_at);
                let mut e = ka.energy_nj + ma.energy_nj + a.energy_nj;
                let mut t = a.done_at;
                if hit.is_none() && l.set1 != l.set0 {
                    let (a2, h2) = scalar.search(l.set1, t);
                    e += a2.energy_nj;
                    t = a2.done_at;
                    hit = h2;
                }
                if hit.is_some() || l.fetch_value_on_miss {
                    if let Some(va) = scalar.ram_access(l.value_block, false, t)
                    {
                        e += va.energy_nj;
                        t = va.done_at;
                    }
                }
                (t, hit.is_some(), e)
            })
            .collect();
        prop_assert!(got.len() == want.len(), "length mismatch");
        for (o, w) in got.iter().zip(&want) {
            prop_assert!(
                o.done_at == w.0 && o.hit == w.1 && o.energy_nj == w.2,
                "lookup diverged: {o:?} vs {w:?}"
            );
        }
        same_state(&batched, &scalar)
    });
}

/// Delegating wrapper that deliberately does NOT override the batched
/// ops, so the trait's provided (scalar) defaults run — the unbatched
/// reference for whole-driver differentials.
struct SequentialOnly(MonarchAssoc);

impl AssocDevice for SequentialOnly {
    fn label(&self) -> &str {
        self.0.label()
    }
    fn static_watts(&self) -> f64 {
        self.0.static_watts()
    }
    fn access(&mut self, addr: u64, write: bool, at: u64)
        -> monarch::mem::Access {
        self.0.access(addr, write, at)
    }
    fn main_access(&mut self, addr: u64, write: bool, at: u64)
        -> monarch::mem::Access {
        self.0.main_access(addr, write, at)
    }
    fn main_static_energy_nj(&self, cycles: u64) -> f64 {
        self.0.main_static_energy_nj(cycles)
    }
    fn cam(&self) -> Option<monarch::device::CamGeom> {
        self.0.cam()
    }
    fn write_key(&mut self, key: u64, at: u64) -> monarch::mem::Access {
        self.0.write_key(key, at)
    }
    fn write_mask(&mut self, mask: u64, at: u64) -> monarch::mem::Access {
        self.0.write_mask(mask, at)
    }
    fn search(&mut self, set: usize, at: u64)
        -> (monarch::mem::Access, Option<usize>) {
        self.0.search(set, at)
    }
    fn cam_write(&mut self, set: usize, col: usize, word: u64, at: u64)
        -> Option<monarch::mem::Access> {
        self.0.cam_write(set, col, word, at)
    }
    fn ram_access(&mut self, block: u64, write: bool, at: u64)
        -> Option<monarch::mem::Access> {
        self.0.ram_access(block, write, at)
    }
    fn drain_energy_nj(&mut self) -> f64 {
        self.0.drain_energy_nj()
    }
    fn reset_timing(&mut self) {
        self.0.reset_timing();
    }
    fn monarch_flat(&self) -> Option<&monarch::monarch::MonarchFlat> {
        self.0.monarch_flat()
    }
}

#[test]
fn ycsb_batched_run_bit_identical_to_unbatched() {
    // The whole-driver differential: run_ycsb with the batched device
    // (one functional evaluation per lookup batch) must produce a
    // bit-identical HashReport to the same driver over a device that
    // only offers the scalar ops.
    for read_pct in [1.0, 0.95, 0.75] {
        let cfg = YcsbConfig {
            table_pow2: 12,
            window: 64, // > 512-column alignment: windows cross sets
            ops: 4000,
            read_pct,
            threads: 8,
            ..Default::default()
        };
        let cam_sets = (1usize << cfg.table_pow2) / 512 + 1;
        let mut batched = MonarchAssoc::new(small_geom(), cam_sets);
        let mut scalar =
            SequentialOnly(MonarchAssoc::new(small_geom(), cam_sets));
        let rb = run_ycsb(&mut batched, &cfg);
        let rs = run_ycsb(&mut scalar, &cfg);
        assert_eq!(rb.cycles, rs.cycles, "cycles @ {read_pct}");
        assert_eq!(rb.hits, rs.hits, "hits @ {read_pct}");
        assert_eq!(rb.ops, rs.ops);
        assert_eq!(rb.rehashes, rs.rehashes);
        assert_eq!(
            rb.energy_nj.to_bits(),
            rs.energy_nj.to_bits(),
            "energy must be bit-identical @ {read_pct}"
        );
        let cb: Vec<_> = rb.counters.iter().collect();
        let cs: Vec<_> = rs.counters.iter().collect();
        assert_eq!(cb, cs, "driver counters @ {read_pct}");
        let fb: Vec<_> =
            batched.flat().stats.iter().collect();
        let fs: Vec<_> =
            scalar.0.flat().stats.iter().collect();
        assert_eq!(fb, fs, "controller stats @ {read_pct}");
    }
}

#[test]
fn hash_report_identical_across_builder_and_direct_construction() {
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 32,
        ops: 2500,
        ..Default::default()
    };
    let geom = small_geom();
    let cam_sets = (1usize << cfg.table_pow2) / 512 + 1;
    let spec = AssocSpec {
        kind: InPackageKind::Monarch { m: 3 },
        capacity_bytes: 0,
        geom,
        cam_sets,
        faults: FaultConfig::default(),
    };
    let mut via_registry = DeviceBuilder::new().build_assoc(&spec);
    let mut direct = assoc::monarch(geom, cam_sets);
    let rr = run_ycsb(via_registry.as_mut(), &cfg);
    let rd = run_ycsb(direct.as_mut(), &cfg);
    assert_eq!(rr.system, rd.system);
    assert_eq!(rr.cycles, rd.cycles);
    assert_eq!(rr.hits, rd.hits);
    assert_eq!(rr.energy_nj.to_bits(), rd.energy_nj.to_bits());
}

#[test]
fn sim_report_identical_across_builder_and_direct_construction() {
    let mk_wl = || SyntheticStream::zipfian(4, 8000, 1 << 21, 0.9, 0.2, 42);
    let cfg = SystemConfig::scaled(InPackageKind::DramCache, 1.0 / 4096.0);
    let mut via_registry = System::build(cfg.clone());
    let r1 = via_registry.run(&mut mk_wl(), u64::MAX);
    let dev = Box::new(TechCache::dram(cfg.inpkg_dram_bytes));
    let mut direct = System::with_device(cfg, dev);
    let r2 = direct.run(&mut mk_wl(), u64::MAX);
    assert_eq!(r1.system, r2.system);
    assert_eq!(r1.cycles, r2.cycles);
    assert_eq!(r1.mem_ops, r2.mem_ops);
    assert_eq!(r1.energy_nj.to_bits(), r2.energy_nj.to_bits());
    let c1: Vec<_> = r1.counters.iter().collect();
    let c2: Vec<_> = r2.counters.iter().collect();
    assert_eq!(c1, c2);
}

#[test]
fn monarch_cache_mode_deterministic_under_trait_dispatch() {
    let run = || {
        let cfg =
            SystemConfig::scaled(InPackageKind::Monarch { m: 3 }, 1.0 / 4096.0);
        let mut sys = System::build(cfg);
        let mut wl = SyntheticStream::zipfian(4, 8000, 1 << 21, 0.9, 0.2, 7);
        let r = sys.run(&mut wl, u64::MAX);
        (r.cycles, r.rotations, r.energy_nj.to_bits())
    };
    assert_eq!(run(), run());
}

#[test]
fn engine_attached_device_matches_fallback_device() {
    // When compiled artifacts (and the `pjrt` feature) are available,
    // a device with the kernel attached must produce bit-identical
    // results to the pure-rust fallback device; otherwise this skips.
    let Some(engine) = monarch::runtime::SearchEngine::load_or_none() else {
        return;
    };
    let mut g = Gen::new(0xC0DE, 256);
    let cam_sets = 8;
    let (mut with_engine, mut fallback) = twin_devices(&mut g, cam_sets);
    with_engine.attach_engine(std::rc::Rc::new(engine));
    let key = with_engine.flat().set_array(3).read_col(17);
    let wave: Vec<SearchOp> =
        (0..cam_sets).map(|s| SearchOp::at(s, key, !0, 5_000)).collect();
    let got = with_engine.search_many(&wave);
    let want = fallback.search_many(&wave);
    assert_eq!(got, want);
}

#[test]
fn sharded_one_shard_reproduces_monarch_reports_bit_identically() {
    // `ShardedAssoc { shards: 1 }` must BE the unsharded backend:
    // whole-driver reports bit-identical across both hashing mixes and
    // string match.
    for read_pct in [1.0, 0.75] {
        let cfg = YcsbConfig {
            table_pow2: 12,
            window: 64,
            ops: 3000,
            read_pct,
            threads: 8,
            ..Default::default()
        };
        let cam_sets = (1usize << cfg.table_pow2) / 512 + 1;
        let mut mono = MonarchAssoc::new(small_geom(), cam_sets);
        let mut one = ShardedAssoc::new(small_geom(), cam_sets, 1);
        let rm = run_ycsb(&mut mono, &cfg);
        let rs = run_ycsb(&mut one, &cfg);
        assert_eq!(rm.system, rs.system, "label @ {read_pct}");
        assert_eq!(rm.cycles, rs.cycles, "cycles @ {read_pct}");
        assert_eq!(rm.hits, rs.hits);
        assert_eq!(rm.rehashes, rs.rehashes);
        assert_eq!(
            rm.energy_nj.to_bits(),
            rs.energy_nj.to_bits(),
            "energy must be bit-identical @ {read_pct}"
        );
        let cm: Vec<_> = rm.counters.iter().collect();
        let cs: Vec<_> = rs.counters.iter().collect();
        assert_eq!(cm, cs, "driver counters @ {read_pct}");
        let fm: Vec<_> = mono.flat().stats.iter().collect();
        let fs: Vec<_> = one.shard_flat(0).stats.iter().collect();
        assert_eq!(fm, fs, "controller stats @ {read_pct}");
        assert!(one.monarch_flat().is_some(), "single shard is THE flat");
    }
    let smc = StringMatchConfig {
        corpus_words: 1 << 13,
        targets: 8,
        threads: 4,
        seed: 11,
    };
    let cam_sets = smc.corpus_words / 512 + 1;
    let mut mono = MonarchAssoc::new(small_geom(), cam_sets);
    let mut one = ShardedAssoc::new(small_geom(), cam_sets, 1);
    let rm = run_string_match(&mut mono, &smc);
    let rs = run_string_match(&mut one, &smc);
    assert_eq!(rm.cycles, rs.cycles);
    assert_eq!(rm.matches, rs.matches);
    assert_eq!(rm.energy_nj.to_bits(), rs.energy_nj.to_bits());
    let cm: Vec<_> = rm.counters.iter().collect();
    let cs: Vec<_> = rs.counters.iter().collect();
    assert_eq!(cm, cs);
}

#[test]
fn sharded_search_many_is_permutation_of_per_shard_scalar_order() {
    // A sharded batch is, per shard, the scalar triple sequence in
    // submission order on that shard's controller — the whole batch is
    // a permutation of those chains, scattered back to submission
    // positions.
    let cam_sets = 16;
    let mk = || ShardedAssoc::bounded(small_geom(), cam_sets, 4, 3);
    let (mut batched, mut scalar) = (mk(), mk());
    assert_eq!(batched.num_shards(), 4);
    let mut g = Gen::new(0xD1CE, 256);
    for _ in 0..64 {
        let (set, col, w) = (g.int(cam_sets), g.int(512), g.u64() | 1);
        let _ = batched.cam_write(set, col, w, 0);
        let _ = scalar.cam_write(set, col, w, 0);
    }
    // plant one repeat key for hit + match-register coverage
    let planted = 0x0DD_B17 | 1;
    let _ = batched.cam_write(9, 100, planted, 0);
    let _ = scalar.cam_write(9, 100, planted, 0);
    let mut ops = Vec::new();
    let mut at = 1_000u64;
    for i in 0..40 {
        at += g.u64() % 200;
        let key = if i % 5 == 0 { planted } else { g.u64() | 1 };
        ops.push(SearchOp { set: g.int(cam_sets), key, mask: !0, at });
    }
    let got = batched.search_many(&ops);
    let mut want: Vec<Option<SearchHit>> = vec![None; ops.len()];
    for s in 0..scalar.num_shards() {
        let idxs: Vec<usize> = (0..ops.len())
            .filter(|&i| scalar.shard_of_set(ops[i].set) == s)
            .collect();
        for &i in &idxs {
            let local = scalar.local_set(ops[i].set);
            let flat = scalar.shard_flat_mut(s);
            let ka = flat.write_key(ops[i].key, ops[i].at);
            let ma = flat.write_mask(ops[i].mask, ka.done_at);
            let (a, hit) = flat.search(local, ma.done_at);
            want[i] = Some(SearchHit {
                done_at: a.done_at,
                col: hit,
                energy_nj: ka.energy_nj + ma.energy_nj + a.energy_nj,
            });
        }
    }
    let want: Vec<SearchHit> =
        want.into_iter().map(|w| w.expect("covered")).collect();
    assert_eq!(got, want, "batched != per-shard scalar chains");
    for s in 0..4 {
        assert_eq!(
            batched.shard_flat(s).keymask(),
            scalar.shard_flat(s).keymask(),
            "shard {s} registers"
        );
        let sb: Vec<_> = batched.shard_flat(s).stats.iter().collect();
        let ss: Vec<_> = scalar.shard_flat(s).stats.iter().collect();
        assert_eq!(sb, ss, "shard {s} stats");
        assert_eq!(
            batched.shard_flat(s).energy_nj,
            scalar.shard_flat(s).energy_nj,
            "shard {s} energy"
        );
    }
}

#[test]
fn sharded_registry_preset_builds_and_runs() {
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 32,
        ops: 1500,
        ..Default::default()
    };
    let cam_sets = (1usize << cfg.table_pow2) / 512 + 1;
    let spec = AssocSpec {
        kind: InPackageKind::MonarchSharded { shards: 4, m: 3 },
        capacity_bytes: 0,
        geom: small_geom(),
        cam_sets,
        faults: FaultConfig::default(),
    };
    let mut dev = DeviceBuilder::new().build_assoc(&spec);
    assert_eq!(dev.label(), "Monarch(S=4)");
    let r = run_ycsb(dev.as_mut(), &cfg);
    assert_eq!(r.ops, cfg.ops as u64);
    assert!(r.cycles > 0);
}

// ---- runtime reconfiguration (PR 3) --------------------------------

/// Issue an identical mixed op sequence (batched waves, window
/// lookups, CAM writes, flat-RAM accesses) and record every
/// observable: completion cycle, energy bits, and outcome.
fn drive_sequence(
    dev: &mut dyn AssocDevice,
    cam_sets: usize,
    seed: u64,
) -> Vec<(u64, u64, i64)> {
    let mut g = Gen::new(seed, 256);
    let mut out = Vec::new();
    let mut at = 1_000_000u64;
    for _ in 0..60 {
        at += 100 + g.u64() % 400;
        match g.int(4) {
            0 => {
                let key = g.u64() | 1;
                let wave: Vec<SearchOp> = (0..cam_sets.min(6))
                    .map(|s| SearchOp::at(s, key, !0, at))
                    .collect();
                for h in dev.search_many(&wave) {
                    out.push((
                        h.done_at,
                        h.energy_nj.to_bits(),
                        h.col.map_or(-1, |c| c as i64),
                    ));
                }
            }
            1 => {
                let l = CamLookup {
                    key: g.u64() | 1,
                    mask: !0,
                    set0: g.int(cam_sets),
                    set1: g.int(cam_sets),
                    value_block: g.u64() % 512,
                    fetch_value_on_miss: g.int(2) == 0,
                    at,
                };
                for o in dev.lookup_many(&[l]) {
                    out.push((
                        o.done_at,
                        o.energy_nj.to_bits(),
                        o.hit as i64,
                    ));
                }
            }
            2 => match dev.cam_write(
                g.int(cam_sets),
                g.int(512),
                g.u64() | 1,
                at,
            ) {
                Some(a) => out.push((a.done_at, a.energy_nj.to_bits(), -2)),
                None => out.push((0, 0, -3)),
            },
            _ => match dev.ram_access(g.u64() % 2048, g.int(2) == 0, at) {
                Some(a) => out.push((a.done_at, a.energy_nj.to_bits(), -4)),
                None => out.push((0, 0, -5)),
            },
        }
    }
    out
}

#[test]
fn reconfigure_pins_constructed_device_unsharded() {
    // The PR-3 correctness anchor: after `reconfigure(m')` on a
    // quiesced device, every subsequent operation is bit-identical to
    // a device CONSTRUCTED at m' holding the same resident data — and
    // the wear counters carry over instead of resetting.
    for (from, to) in [(8usize, 12usize), (12, 5)] {
        let mut g = Gen::new(0xF00D ^ ((from * 100 + to) as u64), 256);
        let mut a = MonarchAssoc::new(small_geom(), from);
        for _ in 0..120 {
            let _ = a.cam_write(g.int(from), g.int(512), g.u64() | 1, 0);
        }
        // dirty the controller: registers, match latch, sense modes
        let _ = a.write_key(0xAB, 500);
        let _ = a.write_mask(!0, 510);
        let _ = a.search(g.int(from), 600);
        let wear_pre = a.flat().wear().write_count();
        assert!(wear_pre > 0, "population must charge wear");
        let out = a.reconfigure(to, 10_000).expect("monarch reconfigures");
        assert_eq!((out.cam_sets_before, out.cam_sets_after), (from, to));
        let wear_post = a.flat().wear().write_count();
        assert!(
            wear_post >= wear_pre,
            "wear must carry over ({wear_post} < {wear_pre})"
        );
        if to > from {
            assert!(
                wear_post > wear_pre,
                "grow relocation must charge the wear leveler"
            );
        }
        // the reference: constructed at `to` with the same residents
        let mut b = MonarchAssoc::new(small_geom(), to);
        for set in 0..to {
            let arr = a.flat().set_array(set);
            for col in 0..arr.cols() {
                let w = arr.read_col(col);
                if w != 0 {
                    b.flat_mut().install_resident(set, col, w);
                }
            }
        }
        let got = drive_sequence(&mut a, to, 0x5EED ^ to as u64);
        let want = drive_sequence(&mut b, to, 0x5EED ^ to as u64);
        assert_eq!(
            got, want,
            "post-reconfigure ops diverged ({from}->{to})"
        );
        assert_eq!(a.flat().keymask(), b.flat().keymask());
    }
}

#[test]
fn reconfigure_pins_constructed_device_sharded() {
    // The sharded half of the anchor: a stride-changing reconfigure
    // (every shard touched, cross-shard set migration) must leave the
    // device bit-identical, for all subsequent ops, to a ShardedAssoc
    // constructed at the target with the same resident data.
    for (from, to) in [(16usize, 24usize), (16, 8)] {
        let mut g = Gen::new(0xCAFE ^ ((from * 100 + to) as u64), 256);
        let mut a = ShardedAssoc::new(small_geom(), from, 4);
        for _ in 0..150 {
            let _ = a.cam_write(g.int(from), g.int(512), g.u64() | 1, 0);
        }
        let _ = a.write_key(0xCD, 500);
        let _ = a.write_mask(!0, 510);
        let _ = a.search(g.int(from), 600);
        let out = a.reconfigure(to, 20_000).expect("sharded reconfigures");
        assert_eq!((out.cam_sets_before, out.cam_sets_after), (from, to));
        let mut b = ShardedAssoc::new(small_geom(), to, 4);
        for gset in 0..to {
            let (s, l) = (a.shard_of_set(gset), a.local_set(gset));
            let arr = a.shard_flat(s).set_array(l);
            for col in 0..arr.cols() {
                let w = arr.read_col(col);
                if w != 0 {
                    let (ds, dl) =
                        (b.shard_of_set(gset), b.local_set(gset));
                    b.shard_flat_mut(ds).install_resident(dl, col, w);
                }
            }
        }
        let got = drive_sequence(&mut a, to, 0xD1D ^ to as u64);
        let want = drive_sequence(&mut b, to, 0xD1D ^ to as u64);
        assert_eq!(
            got, want,
            "sharded post-reconfigure ops diverged ({from}->{to})"
        );
        for s in 0..4 {
            assert_eq!(
                a.shard_flat(s).keymask(),
                b.shard_flat(s).keymask(),
                "shard {s} registers"
            );
            assert!(
                a.shard_flat(s).wear().write_count()
                    >= b.shard_flat(s).wear().write_count(),
                "shard {s} wear must not reset"
            );
        }
    }
}

#[test]
fn one_shard_adaptive_pinned_to_unsharded_adaptive() {
    // `shards: 1` adaptive must BE the unsharded adaptive device:
    // same reconfigure timing, migration cost and whole-driver report,
    // bit for bit.
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 32,
        ops: 6000,
        read_pct: 0.95,
        threads: 8,
        ..Default::default()
    };
    let policy = ReconfigPolicy::default();
    let mut mono = MonarchAssoc::new(small_geom(), 2);
    let mut one = ShardedAssoc::new(small_geom(), 2, 1);
    let rm = run_ycsb_adaptive(&mut mono, &cfg, &policy);
    let rs = run_ycsb_adaptive(&mut one, &cfg, &policy);
    assert!(
        rm.counters.get("reconfigs") >= 1,
        "the overflow config must trip the policy"
    );
    assert_eq!(rm.system, rs.system);
    assert_eq!(rm.cycles, rs.cycles, "adaptive cycles diverged");
    assert_eq!(rm.hits, rs.hits);
    assert_eq!(rm.energy_nj.to_bits(), rs.energy_nj.to_bits());
    let cm: Vec<_> = rm.counters.iter().collect();
    let cs: Vec<_> = rs.counters.iter().collect();
    assert_eq!(cm, cs, "driver counters diverged");
}

#[test]
fn reconfig_sweep_adaptive_beats_spill_only() {
    // The `monarch reconfig` acceptance gate: on the overflow-heavy
    // configs the adaptive device must beat the spill-only device on
    // total cycles (migration cost included) on >= 1 config, and every
    // adaptive cell must actually reconfigure.
    let budget = Budget { hash_ops: 8_000, ..Budget::quick() };
    let pts = coordinator::reconfig_sweep(&budget);
    assert_eq!(pts.len(), 8, "2 configs x 4 systems");
    let mut any_win = false;
    for tp in [12usize, 13] {
        let get = |sys: &str| {
            pts.iter()
                .find(|p| p.table_pow2 == tp && p.system == sys)
                .unwrap_or_else(|| panic!("missing {sys} @ 2^{tp}"))
        };
        let (spill, adapt) = (get("spill"), get("adaptive"));
        assert!(adapt.reconfigs >= 1, "adaptive @ 2^{tp} never grew");
        assert!(
            adapt.final_sets > adapt.start_sets as u64,
            "adaptive @ 2^{tp} must end larger than it started"
        );
        assert!(
            get("adaptive(S=4)").reconfigs >= 1,
            "sharded adaptive @ 2^{tp} never grew"
        );
        any_win |= adapt.cycles < spill.cycles;
    }
    assert!(
        any_win,
        "adaptive must beat spill-only on >= 1 config: {pts:?}"
    );
}

#[test]
fn search_many_wave_matches_individual_searches() {
    // stringmatch-style wave: same key/mask across many sets, all
    // issued at the same cycle
    let cam_sets = 12;
    let (mut batched, mut scalar) = {
        let mut g = Gen::new(0xBEE5, 256);
        twin_devices(&mut g, cam_sets)
    };
    let key = 0xFACE_B00C_0000_0001u64;
    let _ = batched.cam_write(7, 321, key, 0);
    let _ = scalar.cam_write(7, 321, key, 0);
    let wave: Vec<SearchOp> =
        (0..cam_sets).map(|s| SearchOp::at(s, key, !0, 10_000)).collect();
    let got = batched.search_many(&wave);
    let want = sequential_search_many(&mut scalar, &wave);
    assert_eq!(got, want);
    let hits: Vec<usize> = got
        .iter()
        .enumerate()
        .filter(|(_, h)| h.col.is_some())
        .map(|(i, _)| i)
        .collect();
    assert_eq!(hits, vec![7], "only the planted set matches");
}

// ---- cache-mode wave pipeline ---------------------------------------

/// Every registered cache-mode backend kind (the Fig 9 legend plus the
/// scratchpad/flat-RAM miss-through devices).
fn all_cache_kinds() -> Vec<InPackageKind> {
    vec![
        InPackageKind::DramCache,
        InPackageKind::DramCacheIdeal,
        InPackageKind::Sram,
        InPackageKind::RramUnbound,
        InPackageKind::MonarchUnbound,
        InPackageKind::Monarch { m: 1 },
        InPackageKind::Monarch { m: 3 },
        InPackageKind::DramScratchpad,
        InPackageKind::MonarchFlatRam,
    ]
}

fn assert_sim_reports_identical(
    a: &monarch::sim::SimReport,
    b: &monarch::sim::SimReport,
    what: &str,
) {
    assert_eq!(a.system, b.system, "{what}");
    assert_eq!(a.cycles, b.cycles, "{what}: cycles");
    assert_eq!(a.mem_ops, b.mem_ops, "{what}: mem_ops");
    assert_eq!(
        a.l3_hit_rate.to_bits(),
        b.l3_hit_rate.to_bits(),
        "{what}: l3 hit rate"
    );
    assert_eq!(
        a.inpkg_hit_rate.to_bits(),
        b.inpkg_hit_rate.to_bits(),
        "{what}: in-package hit rate"
    );
    assert_eq!(a.rotations, b.rotations, "{what}: rotations");
    assert_eq!(
        a.energy_nj.to_bits(),
        b.energy_nj.to_bits(),
        "{what}: energy"
    );
    let ca: Vec<_> = a.counters.iter().collect();
    let cb: Vec<_> = b.counters.iter().collect();
    assert_eq!(ca, cb, "{what}: counters");
}

#[test]
fn wave_pipeline_bit_identical_to_scalar_for_every_cache_kind() {
    // The end-to-end batching contract of the cache-mode wave
    // pipeline: resolving each wave through one `lookup_many` call
    // must be bit-identical — at whole-`SimReport` level — to
    // resolving the same waves through per-request scalar `lookup`
    // calls, for every registered backend and at every wave cap
    // (1 = the seed's request-at-a-time order).
    for kind in all_cache_kinds() {
        // odd intermediate caps exercise mid-collection resolution;
        // covered on the two backends with real batched/stateful
        // paths to keep the debug-mode suite tractable
        let caps: &[usize] = if matches!(
            kind,
            InPackageKind::Monarch { m: 3 } | InPackageKind::DramCache
        ) {
            &[1, 3, usize::MAX]
        } else {
            &[1, usize::MAX]
        };
        for &cap in caps {
            let run = |scalar: bool| {
                let cfg = SystemConfig::scaled(kind, 1.0 / 4096.0);
                let mut sys = System::build(cfg);
                sys.wave_cap = cap;
                sys.scalar_lookups = scalar;
                let mut wl =
                    SyntheticStream::zipfian(4, 4000, 1 << 21, 0.9, 0.2, 77);
                sys.run(&mut wl, u64::MAX)
            };
            let batched = run(false);
            let scalar = run(true);
            assert_sim_reports_identical(
                &batched,
                &scalar,
                &format!("{kind:?} cap={cap}"),
            );
        }
    }
}

#[test]
fn wave_pipeline_bit_identical_under_graph_workload_with_barriers() {
    // pointer-chase barriers interleave wave resolution with drains;
    // the batched/scalar equivalence must survive that too
    let g = monarch::workloads::graph::Graph::random(2000, 6, 13);
    let wl = monarch::workloads::graph::bfs(&g, 4, 4000);
    for kind in [InPackageKind::Monarch { m: 3 }, InPackageKind::DramCache] {
        let run = |scalar: bool| {
            let cfg = SystemConfig::scaled(kind, 1.0 / 4096.0);
            let mut sys = System::build(cfg);
            sys.scalar_lookups = scalar;
            let mut replay = wl.replay();
            sys.run(&mut replay, u64::MAX)
        };
        let batched = run(false);
        let scalar = run(true);
        assert_sim_reports_identical(&batched, &scalar, &format!("{kind:?}"));
    }
}

// ---- bit-sliced XAM search engine -----------------------------------

/// Every registered software-managed (flat-path) backend kind.
fn all_assoc_kinds() -> Vec<InPackageKind> {
    vec![
        InPackageKind::DramCache,
        InPackageKind::DramScratchpad,
        InPackageKind::Sram,
        InPackageKind::MonarchFlatRam,
        InPackageKind::Monarch { m: 1 },
        InPackageKind::Monarch { m: 3 },
        InPackageKind::MonarchSharded { shards: 4, m: 3 },
        InPackageKind::MonarchAdaptive { m: 3 },
        InPackageKind::MonarchUnbound,
    ]
}

#[test]
fn bitsliced_engine_bit_identical_to_scalar_cache_mode() {
    // The evaluation engine is a host-speed choice only: forcing the
    // scalar per-column engine must leave every whole-run observable
    // bit-identical to the default bit-sliced engine, for every
    // registered cache-mode backend.
    for kind in all_cache_kinds() {
        let run = |scalar: bool| {
            let cfg = SystemConfig::scaled(kind, 1.0 / 4096.0);
            let mut sys = System::build(cfg);
            sys.inpkg.force_scalar_eval(scalar);
            let mut wl =
                SyntheticStream::zipfian(4, 4000, 1 << 21, 0.9, 0.2, 55);
            sys.run(&mut wl, u64::MAX)
        };
        let bitsliced = run(false);
        let scalar = run(true);
        assert_sim_reports_identical(
            &bitsliced,
            &scalar,
            &format!("{kind:?} engine"),
        );
    }
}

#[test]
fn bitsliced_engine_bit_identical_to_scalar_flat_path() {
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 64, // windows cross set boundaries: spill searches too
        ops: 3000,
        read_pct: 0.9,
        threads: 8,
        ..Default::default()
    };
    let cam_sets = (1usize << cfg.table_pow2) / 512 + 1;
    for kind in all_assoc_kinds() {
        let run = |scalar: bool| {
            let spec = AssocSpec {
                kind,
                capacity_bytes: 1 << 18,
                geom: small_geom(),
                cam_sets,
                faults: FaultConfig::default(),
            };
            let mut dev = DeviceBuilder::new().build_assoc(&spec);
            dev.force_scalar_eval(scalar);
            run_ycsb(dev.as_mut(), &cfg)
        };
        let b = run(false);
        let s = run(true);
        assert_eq!(b.system, s.system, "{kind:?}");
        assert_eq!(b.cycles, s.cycles, "{kind:?}: cycles");
        assert_eq!(b.hits, s.hits, "{kind:?}: hits");
        assert_eq!(b.ops, s.ops, "{kind:?}: ops");
        assert_eq!(b.rehashes, s.rehashes, "{kind:?}: rehashes");
        assert_eq!(
            b.energy_nj.to_bits(),
            s.energy_nj.to_bits(),
            "{kind:?}: energy"
        );
        let cb: Vec<_> = b.counters.iter().collect();
        let cs: Vec<_> = s.counters.iter().collect();
        assert_eq!(cb, cs, "{kind:?}: counters");
    }
}

#[test]
fn bitsliced_engine_survives_adaptive_reconfigure_and_stringmatch() {
    // reconfigure grows create new CAM sets mid-run: they must inherit
    // the forced engine — pinned by running the adaptive driver with
    // both engines and comparing whole reports
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 32,
        ops: 6000,
        read_pct: 0.95,
        threads: 8,
        ..Default::default()
    };
    let policy = ReconfigPolicy::default();
    let run = |scalar: bool| {
        let mut dev = MonarchAssoc::new(small_geom(), 2);
        dev.force_scalar_eval(scalar);
        run_ycsb_adaptive(&mut dev, &cfg, &policy)
    };
    let b = run(false);
    let s = run(true);
    assert!(b.counters.get("reconfigs") >= 1, "policy must trip");
    assert_eq!(b.cycles, s.cycles, "adaptive cycles");
    assert_eq!(b.hits, s.hits, "adaptive hits");
    assert_eq!(b.energy_nj.to_bits(), s.energy_nj.to_bits());
    let cb: Vec<_> = b.counters.iter().collect();
    let cs: Vec<_> = s.counters.iter().collect();
    assert_eq!(cb, cs, "adaptive counters");
    // the stringmatch wave driver over the sharded backend: same-key
    // waves across many sets ride the batched bit-sliced sweep
    let smc = StringMatchConfig {
        corpus_words: 1 << 13,
        targets: 8,
        threads: 4,
        seed: 21,
    };
    let sm_sets = smc.corpus_words / 512 + 1;
    let run_sm = |scalar: bool| {
        let mut dev = ShardedAssoc::new(small_geom(), sm_sets, 4);
        dev.force_scalar_eval(scalar);
        run_string_match(&mut dev, &smc)
    };
    let b = run_sm(false);
    let s = run_sm(true);
    assert_eq!(b.cycles, s.cycles, "stringmatch cycles");
    assert_eq!(b.matches, s.matches, "stringmatch matches");
    assert_eq!(b.energy_nj.to_bits(), s.energy_nj.to_bits());
}

// ---- SIMD ISA tiers --------------------------------------------------
//
// The SIMD tier (scalar / sse2 / avx2) is a host-speed choice exactly
// like the engine choice above: every supported tier must leave whole
// reports bit-identical to the forced-scalar tier, on every path. On
// non-x86 hosts `supported_tiers()` is just `[scalar]` and these pass
// trivially; the CI `MONARCH_FORCE_ISA=scalar` leg pins the other
// direction (forced-down default with per-test tiers still live).

#[test]
fn every_isa_tier_bit_identical_cache_mode() {
    for kind in all_cache_kinds() {
        let run = |tier: Isa| {
            let cfg = SystemConfig::scaled(kind, 1.0 / 4096.0);
            let mut sys = System::build(cfg);
            sys.inpkg.force_isa(tier);
            let mut wl =
                SyntheticStream::zipfian(4, 4000, 1 << 21, 0.9, 0.2, 55);
            sys.run(&mut wl, u64::MAX)
        };
        let scalar = run(Isa::Scalar);
        for tier in Isa::supported_tiers() {
            assert_sim_reports_identical(
                &run(tier),
                &scalar,
                &format!("{kind:?} isa={tier}"),
            );
        }
    }
}

#[test]
fn every_isa_tier_bit_identical_flat_path() {
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 64, // windows cross set boundaries: spill searches too
        ops: 3000,
        read_pct: 0.9,
        threads: 8,
        ..Default::default()
    };
    let cam_sets = (1usize << cfg.table_pow2) / 512 + 1;
    for kind in all_assoc_kinds() {
        let run = |tier: Isa| {
            let spec = AssocSpec {
                kind,
                capacity_bytes: 1 << 18,
                geom: small_geom(),
                cam_sets,
                faults: FaultConfig::default(),
            };
            let mut dev = DeviceBuilder::new().build_assoc(&spec);
            dev.force_isa(tier);
            run_ycsb(dev.as_mut(), &cfg)
        };
        let s = run(Isa::Scalar);
        for tier in Isa::supported_tiers() {
            let b = run(tier);
            assert_eq!(b.system, s.system, "{kind:?} isa={tier}");
            assert_eq!(b.cycles, s.cycles, "{kind:?} isa={tier}: cycles");
            assert_eq!(b.hits, s.hits, "{kind:?} isa={tier}: hits");
            assert_eq!(b.ops, s.ops, "{kind:?} isa={tier}: ops");
            assert_eq!(
                b.rehashes,
                s.rehashes,
                "{kind:?} isa={tier}: rehashes"
            );
            assert_eq!(
                b.energy_nj.to_bits(),
                s.energy_nj.to_bits(),
                "{kind:?} isa={tier}: energy"
            );
            let cb: Vec<_> = b.counters.iter().collect();
            let cs: Vec<_> = s.counters.iter().collect();
            assert_eq!(cb, cs, "{kind:?} isa={tier}: counters");
        }
    }
}

#[test]
fn every_isa_tier_survives_adaptive_reconfigure_and_stringmatch() {
    // reconfigure grows create new CAM sets mid-run: they must inherit
    // the forced tier, exactly like the forced engine
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 32,
        ops: 6000,
        read_pct: 0.95,
        threads: 8,
        ..Default::default()
    };
    let policy = ReconfigPolicy::default();
    let run = |tier: Isa| {
        let mut dev = MonarchAssoc::new(small_geom(), 2);
        dev.force_isa(tier);
        run_ycsb_adaptive(&mut dev, &cfg, &policy)
    };
    let s = run(Isa::Scalar);
    assert!(s.counters.get("reconfigs") >= 1, "policy must trip");
    for tier in Isa::supported_tiers() {
        let b = run(tier);
        assert_eq!(b.cycles, s.cycles, "adaptive isa={tier}: cycles");
        assert_eq!(b.hits, s.hits, "adaptive isa={tier}: hits");
        assert_eq!(b.energy_nj.to_bits(), s.energy_nj.to_bits());
        let cb: Vec<_> = b.counters.iter().collect();
        let cs: Vec<_> = s.counters.iter().collect();
        assert_eq!(cb, cs, "adaptive isa={tier}: counters");
    }
    // the stringmatch wave driver over the sharded backend rides both
    // the SIMD wave sweep and the multicore per-shard eval fan-out
    let smc = StringMatchConfig {
        corpus_words: 1 << 13,
        targets: 8,
        threads: 4,
        seed: 21,
    };
    let sm_sets = smc.corpus_words / 512 + 1;
    let run_sm = |tier: Isa| {
        let mut dev = ShardedAssoc::new(small_geom(), sm_sets, 4);
        dev.force_isa(tier);
        run_string_match(&mut dev, &smc)
    };
    let s = run_sm(Isa::Scalar);
    for tier in Isa::supported_tiers() {
        let b = run_sm(tier);
        assert_eq!(b.cycles, s.cycles, "stringmatch isa={tier}: cycles");
        assert_eq!(
            b.matches,
            s.matches,
            "stringmatch isa={tier}: matches"
        );
        assert_eq!(b.energy_nj.to_bits(), s.energy_nj.to_bits());
    }
}

#[test]
fn every_isa_tier_preserves_service_fingerprint() {
    // the production service driver hashes exactly the modeled fields
    // into a replayable fingerprint; every ISA tier must reproduce the
    // forced-scalar fingerprint on the sharded backend
    let budget = Budget { hash_ops: 900, ..Budget::quick() };
    let (meta, reqs) = coordinator::service_traffic(&budget, 2.0);
    let geom = MonarchGeom::FULL.scaled(budget.scale * 4.0);
    let run = |tier: Isa| {
        let spec = AssocSpec {
            kind: InPackageKind::MonarchSharded { shards: 4, m: 3 },
            capacity_bytes: 0,
            geom,
            cam_sets: meta.num_sets as usize,
            faults: FaultConfig::default(),
        };
        let mut dev = DeviceBuilder::new().build_assoc(&spec);
        dev.force_isa(tier);
        run_service(dev.as_mut(), &ServiceConfig::default(), &meta, &reqs)
    };
    let s = run(Isa::Scalar);
    for tier in Isa::supported_tiers() {
        assert_eq!(
            run(tier).modeled_fingerprint(),
            s.modeled_fingerprint(),
            "service fingerprint isa={tier}"
        );
    }
}

// ---- hybrid MemCache split extremes ---------------------------------

/// Numeric whole-report comparison for devices whose labels legally
/// differ (the hybrid extremes report "Monarch(hybrid,...)" while the
/// single-mode controllers report "Monarch(M=3)" / "Monarch").
fn assert_sim_reports_numerically_identical(
    a: &monarch::sim::SimReport,
    b: &monarch::sim::SimReport,
    what: &str,
) {
    assert_eq!(a.cycles, b.cycles, "{what}: cycles");
    assert_eq!(a.mem_ops, b.mem_ops, "{what}: mem_ops");
    assert_eq!(
        a.l3_hit_rate.to_bits(),
        b.l3_hit_rate.to_bits(),
        "{what}: l3 hit rate"
    );
    assert_eq!(
        a.inpkg_hit_rate.to_bits(),
        b.inpkg_hit_rate.to_bits(),
        "{what}: in-package hit rate"
    );
    assert_eq!(a.rotations, b.rotations, "{what}: rotations");
    assert_eq!(
        a.energy_nj.to_bits(),
        b.energy_nj.to_bits(),
        "{what}: energy"
    );
    let ca: Vec<_> = a.counters.iter().collect();
    let cb: Vec<_> = b.counters.iter().collect();
    assert_eq!(ca, cb, "{what}: counters");
}

#[test]
fn hybrid_all_cache_extreme_bit_identical_to_monarch_cache() {
    // cache_vaults = all: the hybrid has no flat region and every
    // CacheDevice call is pure delegation to the embedded MonarchCache
    // built from the same geometry/wear/window — whole SimReports must
    // be bit-identical to the plain Monarch cache-mode device.
    let scale = 1.0 / 4096.0;
    let vaults =
        SystemConfig::scaled(InPackageKind::DramCache, scale).monarch.vaults;
    let run = |kind: InPackageKind| {
        let cfg = SystemConfig::scaled(kind, scale);
        let mut sys = System::build(cfg);
        let mut wl = SyntheticStream::zipfian(4, 4000, 1 << 21, 0.9, 0.2, 77);
        sys.run(&mut wl, u64::MAX)
    };
    let plain = run(InPackageKind::Monarch { m: 3 });
    let hybrid =
        run(InPackageKind::MonarchHybrid { cache_vaults: vaults, m: 3 });
    assert_eq!(hybrid.system, format!("Monarch(hybrid,C={vaults},M=3)"));
    assert_sim_reports_numerically_identical(
        &plain,
        &hybrid,
        "all-cache extreme",
    );
}

#[test]
fn hybrid_all_memory_extreme_bit_identical_to_monarch_assoc() {
    // cache_vaults = 0: the hybrid's AssocDevice surface is the same
    // MonarchFlat + MainMemory composition as MonarchAssoc (same wear
    // config, same window), and the software path never trips the
    // promotion policy — whole HashReports must agree numerically.
    use monarch::config::WearConfig;
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 64,
        ops: 3000,
        read_pct: 0.9,
        threads: 8,
        ..Default::default()
    };
    let cam_sets = (1usize << cfg.table_pow2) / 512 + 1;
    let mut plain = MonarchAssoc::bounded(small_geom(), cam_sets, 3);
    let mut hybrid = monarch::monarch::MonarchHybrid::new(
        small_geom(),
        0,
        cam_sets,
        WearConfig::default_m(3),
        u64::MAX / 4,
        true,
    );
    let p = run_ycsb(&mut plain, &cfg);
    let h = run_ycsb(&mut hybrid, &cfg);
    assert_eq!(h.system, "Monarch(hybrid,C=0,M=3)");
    assert_eq!(p.cycles, h.cycles, "cycles");
    assert_eq!(p.ops, h.ops, "ops");
    assert_eq!(p.hits, h.hits, "hits");
    assert_eq!(p.rehashes, h.rehashes, "rehashes");
    assert_eq!(p.energy_nj.to_bits(), h.energy_nj.to_bits(), "energy");
    let cp: Vec<_> = p.counters.iter().collect();
    let ch: Vec<_> = h.counters.iter().collect();
    assert_eq!(cp, ch, "counters");
    // and the device kept zero promotion state
    assert_eq!(hybrid.resident_pages(), 0);
}

#[test]
fn cachewave_monarch_scales_while_scalar_fallback_stays_flat() {
    // The `monarch cachewave` acceptance gate: Monarch's batched
    // `lookup_many` aggregates wider waves into fewer functional
    // evaluations (lookups/eval grows with the cap) and its modeled
    // throughput rises as fills defer behind the wave's demand
    // lookups; `TechCache` rides the scalar `lookup_many` fallback —
    // no batched evaluations, occupancy pinned flat at 1.
    let budget = Budget {
        trace_ops: 4000,
        threads: 4,
        ..Budget::quick()
    };
    let pts = coordinator::cachewave_sweep(&budget, &[1, 4, 0]);
    let of = |sys: &str, cap: usize| {
        pts.iter()
            .find(|p| p.system == sys && p.wave_cap == cap)
            .unwrap_or_else(|| panic!("missing cell {sys} cap={cap}"))
            .clone()
    };
    for sys in ["Monarch(M=3)", "M-Unbound"] {
        let (w1, w4, wmax) = (of(sys, 1), of(sys, 4), of(sys, 0));
        assert!(
            wmax.lookups_per_eval > w4.lookups_per_eval
                && w4.lookups_per_eval >= w1.lookups_per_eval,
            "{sys}: occupancy must scale with the cap: \
             {} / {} / {}",
            w1.lookups_per_eval,
            w4.lookups_per_eval,
            wmax.lookups_per_eval
        );
        assert!(
            wmax.lookups_per_eval > 1.5,
            "{sys}: unbounded waves must batch ({})",
            wmax.lookups_per_eval
        );
        assert!(
            wmax.ops_per_kcycle > w1.ops_per_kcycle,
            "{sys}: wave throughput must beat scalar-order resolve \
             ({} vs {})",
            wmax.ops_per_kcycle,
            w1.ops_per_kcycle
        );
    }
    for p in pts.iter().filter(|p| p.system == "D-Cache") {
        assert_eq!(
            p.lookups_per_eval, 1.0,
            "scalar fallback cannot aggregate (cap={})",
            p.wave_cap
        );
    }
}

// ---- fault injection (graceful degradation) -------------------------

#[test]
fn disabled_fault_config_is_bit_identical_to_unarmed() {
    // The zero-cost pin: explicitly arming a device with the default
    // (disabled) FaultConfig must leave every observable — completion
    // cycles, energy bits, hit columns — bit-identical to never
    // touching the fault surface at all, on both the unsharded and
    // sharded backends.
    let cam_sets = 8usize;
    for kind in [
        InPackageKind::Monarch { m: 3 },
        InPackageKind::MonarchSharded { shards: 4, m: 3 },
    ] {
        let run = |arm: bool| {
            let spec = AssocSpec {
                kind,
                capacity_bytes: 0,
                geom: small_geom(),
                cam_sets,
                faults: FaultConfig::default(),
            };
            let mut dev = DeviceBuilder::new().build_assoc(&spec);
            if arm {
                dev.set_fault_config(FaultConfig::default());
            }
            let out = drive_sequence(dev.as_mut(), cam_sets, 0xFA17);
            let clean = dev.fault_totals().is_none_or(|t| !t.any());
            (out, clean)
        };
        let (armed, armed_clean) = run(true);
        let (unarmed, unarmed_clean) = run(false);
        assert_eq!(
            armed, unarmed,
            "{kind:?}: arming a disabled FaultConfig changed behaviour"
        );
        assert!(
            armed_clean && unarmed_clean,
            "{kind:?}: fault totals nonzero without injection"
        );
    }
}

#[test]
fn fault_campaign_degrades_ycsb_without_corruption() {
    // Stuck-at + transient injection under the YCSB driver: the
    // faulted run must complete every op with IDENTICAL functional
    // results — the software table is the source of truth, and a lost
    // CAM word may only cost time (the lookup falls through to the
    // main-memory image), never corrupt an answer — while the damage
    // stays visible in the fault totals.
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 32,
        ops: 3000,
        ..Default::default()
    };
    let cam_sets = (1usize << cfg.table_pow2) / 512 + 1;
    let run = |faults: FaultConfig| {
        let spec = AssocSpec {
            kind: InPackageKind::MonarchSharded { shards: 4, m: 3 },
            capacity_bytes: 0,
            geom: small_geom(),
            cam_sets,
            faults,
        };
        let mut dev = DeviceBuilder::new().build_assoc(&spec);
        let r = run_ycsb(dev.as_mut(), &cfg);
        (r, dev.fault_totals().expect("sharded Monarch tracks totals"))
    };
    let (clean, ct) = run(FaultConfig::default());
    assert!(!ct.any(), "clean run reports damage: {ct:?}");
    let (faulted, ft) = run(FaultConfig {
        seed: 11,
        stuck_per_mille: 50,
        transient_pct: 10.0,
        max_retries: 1,
        ..FaultConfig::default()
    });
    assert_eq!(faulted.ops, clean.ops, "faulted run dropped ops");
    assert!(faulted.cycles > 0);
    assert!(ft.any(), "campaign injected nothing");
    assert!(
        ft.retired_columns > 0,
        "heavy campaign retired no columns: {ft:?}"
    );
    assert_eq!(
        faulted.hits, clean.hits,
        "faulted run changed functional results — fault injection must \
         degrade timing and capacity, never answers"
    );
}

#[test]
fn every_isa_tier_preserves_faulted_service_fingerprint() {
    // Fault draws are pure functions of (seed, coordinates), never of
    // the engine evaluating the search: an armed campaign must yield
    // the same fingerprint AND the same fault totals on every ISA tier.
    let budget = Budget { hash_ops: 900, ..Budget::quick() };
    let (meta, reqs) = coordinator::service_traffic(&budget, 2.0);
    let geom = MonarchGeom::FULL.scaled(budget.scale * 4.0);
    let faults = FaultConfig {
        seed: 7,
        stuck_per_mille: 20,
        transient_pct: 5.0,
        max_retries: 2,
        ..FaultConfig::default()
    };
    let run = |tier: Isa| {
        let spec = AssocSpec {
            kind: InPackageKind::MonarchSharded { shards: 4, m: 3 },
            capacity_bytes: 0,
            geom,
            cam_sets: meta.num_sets as usize,
            faults,
        };
        let mut dev = DeviceBuilder::new().build_assoc(&spec);
        dev.force_isa(tier);
        run_service(dev.as_mut(), &ServiceConfig::default(), &meta, &reqs)
    };
    let s = run(Isa::Scalar);
    assert!(
        s.fault_totals.expect("sharded Monarch tracks totals").any(),
        "campaign injected nothing at this scale"
    );
    for tier in Isa::supported_tiers() {
        let r = run(tier);
        assert_eq!(
            r.modeled_fingerprint(),
            s.modeled_fingerprint(),
            "faulted service fingerprint isa={tier}"
        );
        assert_eq!(
            r.fault_totals, s.fault_totals,
            "fault totals diverged isa={tier}"
        );
    }
}
