//! Cross-module integration tests: full-system runs, flat-mode flows,
//! runtime bridge, and determinism.

use monarch::config::{InPackageKind, MonarchGeom, SystemConfig, WearConfig};
use monarch::device::assoc;
use monarch::monarch::MonarchFlat;
use monarch::runtime::SearchEngine;
use monarch::sim::System;
use monarch::workloads::hashing::{run_ycsb, YcsbConfig};
use monarch::workloads::{graph, SyntheticStream, Workload};

fn scaled(kind: InPackageKind) -> SystemConfig {
    SystemConfig::scaled(kind, 1.0 / 4096.0)
}

#[test]
fn full_system_graph_run_all_inpkg_kinds() {
    let g = graph::Graph::random(4000, 6, 11);
    let wl = graph::bfs(&g, 4, 4000);
    let kinds = [
        InPackageKind::DramCache,
        InPackageKind::DramCacheIdeal,
        InPackageKind::Sram,
        InPackageKind::RramUnbound,
        InPackageKind::MonarchUnbound,
        InPackageKind::Monarch { m: 1 },
        InPackageKind::Monarch { m: 3 },
    ];
    for kind in kinds {
        let mut sys = System::build(scaled(kind));
        let mut replay = wl.replay();
        let r = sys.run(&mut replay, u64::MAX);
        assert!(r.cycles > 0, "{kind:?}");
        assert!(r.mem_ops > 0, "{kind:?}");
        assert!(r.energy_nj > 0.0, "{kind:?}");
    }
}

#[test]
fn allocator_growth_drives_device_reconfigure() {
    // The OS-level handoff: a `flat_cam_malloc` past the backed CAM
    // capacity grows the window, and the pending `cam_grew()`
    // notification translates into a device `reconfigure` that backs
    // the new capacity — after which the region is really searchable.
    use monarch::device::AssocDevice;
    use monarch::monarch::alloc::Allocator;

    let geom = MonarchGeom {
        vaults: 4,
        banks_per_vault: 8,
        supersets_per_bank: 8,
        sets_per_superset: 8,
        rows_per_set: 64,
        cols_per_set: 512,
        layers: 1,
    };
    let set_bytes = geom.set_bytes() as u64; // 4096B per set
    let start_sets = 2usize;
    let mut dev = assoc::MonarchAssoc::new(geom, start_sets);
    let mut alloc = Allocator::reconfigurable(
        1 << 20,
        1 << 20,
        start_sets as u64 * set_bytes,
        16 * set_bytes,
    );
    // fill the backed window, then allocate past it
    let _ = alloc.flat_cam_malloc(start_sets as u64 * set_bytes).unwrap();
    assert!(alloc.cam_grew().is_none());
    let r2 = alloc.flat_cam_malloc(2 * set_bytes).unwrap();
    let new_cap = alloc.cam_grew().expect("growth pending");
    assert!(new_cap >= 4 * set_bytes);
    // translate bytes -> sets and back the capacity on the device
    let target_sets = new_cap.div_ceil(set_bytes) as usize;
    let out = dev
        .reconfigure(target_sets, 1_000)
        .expect("monarch devices reconfigure");
    assert_eq!(out.cam_sets_after, target_sets);
    assert_eq!(
        dev.cam().unwrap().num_sets as u64 * set_bytes,
        alloc.cam_capacity().div_ceil(set_bytes) * set_bytes,
        "device partition backs the allocator capacity"
    );
    // the grown region is really searchable: plant a word in the set
    // holding r2 and find it
    let word_index =
        ((r2.base - monarch::monarch::alloc::FLAT_CAM_BASE) / 8) as usize;
    let (set, col) = (word_index / 512, word_index % 512);
    assert!(set >= start_sets, "the new region lives in grown sets");
    let _ = dev.cam_write(set, col, 0xFACE, out.done_at);
    let ka = dev.write_key(0xFACE, out.done_at + 1_000);
    let ma = dev.write_mask(!0, ka.done_at);
    let (_, hit) = dev.search(set, ma.done_at);
    assert_eq!(hit, Some(col), "grown partition must be searchable");
}

#[test]
fn simulation_is_deterministic() {
    let run = || {
        let mut sys = System::build(scaled(InPackageKind::Monarch { m: 3 }));
        let mut wl = SyntheticStream::zipfian(4, 8000, 1 << 21, 0.9, 0.2, 99);
        sys.run(&mut wl, u64::MAX).cycles
    };
    assert_eq!(run(), run(), "same seed must reproduce exactly");
}

#[test]
fn ycsb_functional_results_identical_across_systems() {
    let cfg = YcsbConfig {
        table_pow2: 12,
        window: 32,
        ops: 2500,
        read_pct: 0.9,
        ..Default::default()
    };
    let geom = MonarchGeom::FULL.scaled(1.0 / 1024.0);
    let table_bytes = (1usize << cfg.table_pow2) * 24;
    let mut reports = Vec::new();
    for mut sys in [
        assoc::hbm_c(table_bytes),
        assoc::hbm_sp(table_bytes),
        assoc::cmos(table_bytes / 8),
        assoc::rram_flat(table_bytes * 2),
        assoc::monarch(geom, (1 << cfg.table_pow2) / 512 + 1),
    ] {
        reports.push(run_ycsb(sys.as_mut(), &cfg));
    }
    // identical logical work: same hits everywhere
    for r in &reports[1..] {
        assert_eq!(r.hits, reports[0].hits, "{} diverged", r.system);
        assert_eq!(r.ops, reports[0].ops);
    }
}

#[test]
fn flat_cam_full_fig6_flow_with_runtime_crosscheck() {
    let geom = MonarchGeom {
        vaults: 2,
        banks_per_vault: 4,
        supersets_per_bank: 4,
        sets_per_superset: 8,
        rows_per_set: 64,
        cols_per_set: 512,
        layers: 1,
    };
    let mut m =
        MonarchFlat::new(geom, 4, WearConfig::default_m(3), u64::MAX / 4, true);
    let mut t = 0;
    for col in 0..128 {
        t = m.cam_write(1, col, 0xAB00 + col as u64, t).unwrap().done_at;
    }
    t = m.write_key(0xAB00 + 77, t).done_at;
    t = m.write_mask(!0, t).done_at;
    let (_, hit) = m.search(1, t);
    assert_eq!(hit, Some(77));
    // cross-check with the compiled kernel when artifacts exist;
    // degrades gracefully (pure-rust path is the test body) otherwise
    if let Some(engine) = SearchEngine::load_or_none() {
        let (key, mask) = m.keymask();
        let got =
            engine.search_sets(&[m.set_array(1)], &[key], &[mask]).unwrap();
        assert_eq!(got, vec![Some(77)]);
    }
}

#[test]
fn m_sweep_orders_reasonably() {
    // tighter write budgets can only slow things down (Fig 9 M sweep)
    let g = graph::Graph::random(3000, 6, 5);
    let wl = graph::sssp(&g, 4, 6000, 4);
    let mut cycles = Vec::new();
    for m in [1u32, 4] {
        let mut sys = System::build(scaled(InPackageKind::Monarch { m }));
        let mut replay = wl.replay();
        cycles.push(sys.run(&mut replay, u64::MAX).cycles);
    }
    // M=1 (most restrictive) must not be faster than M=4 by more than
    // simulator noise
    assert!(
        cycles[0] as f64 >= cycles[1] as f64 * 0.98,
        "M=1 {} vs M=4 {}",
        cycles[0],
        cycles[1]
    );
}

#[test]
fn workload_replay_is_stable() {
    let g = graph::Graph::random(1000, 4, 3);
    let wl = graph::pagerank(&g, 2, 2000, 2);
    let drain = |mut w: monarch::workloads::TraceWorkload| {
        let mut v = Vec::new();
        for t in 0..2 {
            while let Some(op) = w.next_op(t) {
                v.push((t, op.addr, op.write));
            }
        }
        v
    };
    assert_eq!(drain(wl.replay()), drain(wl.replay()));
}
