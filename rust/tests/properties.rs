//! Property-based tests (hand-rolled driver in `util::prop`) over the
//! coordinator invariants: address remapping stays a bijection,
//! diagonal selection is conflict-free, t_MWW never exceeds budget,
//! the hopscotch table preserves its window rule, and the XAM array
//! search agrees with a naive bit-by-bit model under arbitrary
//! write/search sequences.

use monarch::config::WearConfig;
use monarch::monarch::alloc::{
    self, space_of, Allocator, Region, Space,
};
use monarch::monarch::wear::{Endure, MwwWindow, Offsets, WearLeveler};
use monarch::prop_assert;
use monarch::util::prop::{check, Gen};
use monarch::workloads::hashing::{Hopscotch, InsertOutcome};
use monarch::xam::superset::{diagonal_select, diagonal_set};
use monarch::xam::{ColWrite, FaultConfig, Isa, SearchScratch, XamArray};

#[test]
fn prop_remap_is_bijective() {
    check("remap_bijective", 40, |g: &mut Gen| {
        let nv = 1 + g.int(8);
        let nb = 1 + g.int(64);
        let nss = 1 + g.int(64);
        let nset = 1 + g.int(8);
        let mut wl = WearLeveler::new(WearConfig::default_m(3), 8, u64::MAX);
        for _ in 0..g.int(20) {
            wl.offsets.rotate();
        }
        let mut seen = std::collections::HashSet::new();
        for v in 0..nv {
            for b in 0..nb {
                for ss in 0..nss.min(8) {
                    for s in 0..nset {
                        let out = wl.remap(v, b, ss, s, nv, nb, nss, nset);
                        prop_assert!(
                            seen.insert(out),
                            "collision at {v},{b},{ss},{s} -> {out:?}"
                        );
                        prop_assert!(
                            out.0 < nv && out.1 < nb && out.2 < nss
                                && out.3 < nset,
                            "out of range: {out:?}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_diagonal_partition() {
    check("diagonal_partition", 30, |g: &mut Gen| {
        let grid = 1 + g.int(16);
        let mut count = vec![0usize; grid];
        for i in 0..grid {
            for j in 0..grid {
                count[diagonal_set(grid, i, j)] += 1;
            }
        }
        prop_assert!(
            count.iter().all(|&c| c == grid),
            "not a partition: {count:?}"
        );
        for k in 0..grid {
            let sel = diagonal_select(grid, k);
            for &(i, j) in &sel {
                prop_assert!(
                    diagonal_set(grid, i, j) == k,
                    "selection disagrees at ({i},{j})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mww_budget_never_exceeded() {
    check("mww_budget", 50, |g: &mut Gen| {
        let m = 1 + g.int(4) as u32;
        let window = 100 + g.u64() % 10_000;
        let mut w = MwwWindow::default();
        let mut now = 0u64;
        let mut in_window = 0u32;
        let mut window_start = 0u64;
        for _ in 0..5000 {
            now += g.u64() % 50;
            if w.record_write(now, window, m) {
                if now >= window_start + window {
                    window_start = now;
                    in_window = 0;
                }
                in_window += 1;
                prop_assert!(
                    in_window <= 512 * m,
                    "budget exceeded: {in_window} > {}",
                    512 * m
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_offsets_prime_strides() {
    check("offset_strides", 20, |g: &mut Gen| {
        let mut o = Offsets::default();
        let n = 1 + g.int(100) as u64;
        for _ in 0..n {
            o.rotate();
        }
        prop_assert!(o.bank == n, "bank stride 1");
        prop_assert!(o.set == 3 * n, "set stride 3");
        prop_assert!(o.superset == 7 * n, "superset stride 7");
        prop_assert!(o.vault == 5 * (n / 8), "vault stride 5 every 8");
        Ok(())
    });
}

#[test]
fn prop_hopscotch_window_invariant() {
    check("hopscotch_window", 25, |g: &mut Gen| {
        let pow = 7 + g.int(3);
        let window = 8 << g.int(3);
        let mut t = Hopscotch::new(pow, window);
        let mut inserted = Vec::new();
        for _ in 0..(1 << pow) {
            let key = g.u64() | 1;
            match t.insert(key) {
                InsertOutcome::Inserted { .. } => inserted.push(key),
                InsertOutcome::NeedRehash => break,
                InsertOutcome::AlreadyPresent => {}
            }
        }
        // every inserted key is findable and within its window
        let n = t.buckets.len();
        for key in &inserted {
            let (found, probes) = t.lookup(*key);
            prop_assert!(found.is_some(), "lost key {key}");
            prop_assert!(probes <= window, "probes {probes} > window");
            let i = found.unwrap();
            let dist = (i + n - t.home(*key)) & (n - 1);
            prop_assert!(dist < window, "key {key} at distance {dist}");
        }
        Ok(())
    });
}

#[test]
fn prop_xam_search_matches_naive_model() {
    check("xam_vs_naive", 40, |g: &mut Gen| {
        let rows = 1 + g.int(64).clamp(0, 63);
        let cols = 1 + g.int(128);
        let mut a = XamArray::new(rows, cols);
        let mut model = vec![0u64; cols];
        let row_mask =
            if rows == 64 { !0u64 } else { (1u64 << rows) - 1 };
        for _ in 0..g.int(200) {
            match g.int(4) {
                0 => {
                    let c = g.int(cols).min(cols - 1);
                    let w = g.u64();
                    a.write_col(c, w);
                    model[c] = w & row_mask;
                }
                1 => {
                    let r = g.int(rows).min(rows - 1);
                    let bits = g.u64();
                    a.write_row(r, bits, 64);
                    for (j, m) in
                        model.iter_mut().enumerate().take(cols.min(64))
                    {
                        if (bits >> j) & 1 == 1 {
                            *m |= 1 << r;
                        } else {
                            *m &= !(1 << r);
                        }
                    }
                }
                _ => {
                    let key = g.u64();
                    let mask = g.u64();
                    let naive: Option<usize> = model
                        .iter()
                        .position(|&w| (w ^ key) & mask & row_mask == 0);
                    let got = a.search_first(key, mask);
                    prop_assert!(
                        got == naive,
                        "search mismatch: got {got:?} want {naive:?}"
                    );
                }
            }
        }
        // full state agreement at the end
        for (c, &m) in model.iter().enumerate() {
            prop_assert!(
                a.read_col(c) == m,
                "state diverged at column {c}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_bitsliced_engine_matches_scalar() {
    // The bit-sliced plane engine and the scalar per-column engine
    // must agree on every observable — first match, match count,
    // per-column flags, batched waves — for arbitrary geometries
    // (rows < 64, cols off the 64 grid), masks (zero, partial-byte,
    // single-bit, random) and interleaved write_col/write_row
    // sequences that stress plane coherence.
    check("bitsliced_vs_scalar", 40, |g: &mut Gen| {
        let rows = 1 + g.int(64).min(63);
        let cols = 1 + g.int(600);
        let mut a = XamArray::new(rows, cols);
        for _ in 0..g.int(300) {
            if g.int(3) == 0 {
                a.write_row(g.int(rows).min(rows - 1), g.u64(), g.int(65));
            } else {
                a.write_col(g.int(cols).min(cols - 1), g.u64());
            }
        }
        let mut scalar = a.clone();
        scalar.force_scalar(true);
        let mut sb = SearchScratch::new();
        let mut ss = SearchScratch::new();
        for trial in 0..24usize {
            let key = match trial % 3 {
                0 => g.u64(),
                1 => a.read_col(g.int(cols).min(cols - 1)),
                _ => 0,
            };
            let mask = match trial % 5 {
                0 => !0u64,
                1 => 0,
                2 => 0xFF00, // partial-byte mask
                3 => 1u64 << g.int(64).min(63),
                _ => g.u64(),
            };
            prop_assert!(
                a.search_first(key, mask) == scalar.search_first(key, mask),
                "first diverged (rows={rows} cols={cols} key={key:#x} \
                 mask={mask:#x})"
            );
            let got = a.search_into(key, mask, &mut sb);
            let want = scalar.search_into(key, mask, &mut ss);
            prop_assert!(
                got == want,
                "outcome diverged: {got:?} vs {want:?} (key={key:#x} \
                 mask={mask:#x})"
            );
            prop_assert!(
                sb.match_words() == ss.match_words(),
                "match flags diverged (key={key:#x} mask={mask:#x})"
            );
        }
        // a batched wave against the same array, mixed masks
        let n = 1 + g.int(24);
        let keys: Vec<u64> = (0..n)
            .map(|i| {
                if i % 2 == 0 {
                    g.u64()
                } else {
                    a.read_col(g.int(cols).min(cols - 1))
                }
            })
            .collect();
        let masks: Vec<u64> = (0..n)
            .map(|i| match i % 4 {
                0 => !0u64,
                1 => 0xFFFF,
                2 => 0,
                _ => g.u64(),
            })
            .collect();
        let mut out = Vec::new();
        a.search_many_bitsliced(&keys, &masks, &mut sb, &mut out);
        prop_assert!(out.len() == n, "wave result length");
        for (i, got) in out.iter().enumerate() {
            prop_assert!(
                *got == scalar.search_first(keys[i], masks[i]),
                "wave member {i} diverged (key={:#x} mask={:#x})",
                keys[i],
                masks[i]
            );
        }
        // plane-backed read_row agrees with the column image
        for r in 0..rows {
            let mut want = 0u64;
            for j in 0..cols.min(64) {
                want |= ((a.read_col(j) >> r) & 1) << j;
            }
            prop_assert!(a.read_row(r) == want, "read_row({r}) diverged");
        }
        Ok(())
    });
}

#[test]
fn prop_simd_tiers_match_scalar_sweep() {
    // Every supported SIMD tier of the plane sweep must agree with
    // the forced-scalar tier — and with the per-column scalar engine
    // — on arbitrary off-grid geometries (cols straddling the 64-,
    // 128- and 256-bit lane boundaries), partial masks and
    // write-driven plane coherence storms interleaved with searches.
    // On non-x86 hosts `supported_tiers()` is `[scalar]` and this
    // reduces to the engine property above.
    check("simd_tiers_vs_scalar", 40, |g: &mut Gen| {
        let rows = 1 + g.int(64).min(63);
        // bias cols toward the lane edges the SIMD remainder handles:
        // 1..=4 words of planes plus an off-grid tail
        let cols = match g.int(4) {
            0 => 1 + g.int(64),
            1 => 63 + g.int(4),   // straddle one word
            2 => 255 + g.int(6),  // straddle the AVX2 stride
            _ => 1 + g.int(600),
        };
        let mut tiers: Vec<XamArray> = Isa::supported_tiers()
            .into_iter()
            .map(|t| {
                let mut a = XamArray::new(rows, cols);
                a.force_isa(t);
                a
            })
            .collect();
        let mut scalar = XamArray::new(rows, cols);
        scalar.force_scalar(true);
        let mut sb = SearchScratch::new();
        let mut ss = SearchScratch::new();
        for storm in 0..3usize {
            // a coherence storm: writes that dirty planes mid-stream
            for _ in 0..g.int(120) {
                if g.int(3) == 0 {
                    let (r, w, n) =
                        (g.int(rows).min(rows - 1), g.u64(), g.int(65));
                    for a in tiers.iter_mut() {
                        a.write_row(r, w, n);
                    }
                    scalar.write_row(r, w, n);
                } else {
                    let (c, w) = (g.int(cols).min(cols - 1), g.u64());
                    for a in tiers.iter_mut() {
                        a.write_col(c, w);
                    }
                    scalar.write_col(c, w);
                }
            }
            for trial in 0..12usize {
                let key = match trial % 3 {
                    0 => g.u64(),
                    1 => scalar.read_col(g.int(cols).min(cols - 1)),
                    _ => 0,
                };
                let mask = match trial % 5 {
                    0 => !0u64,
                    1 => 0,
                    2 => 0xFF00,
                    3 => 1u64 << g.int(64).min(63),
                    _ => g.u64(),
                };
                let want_first = scalar.search_first(key, mask);
                let want = scalar.search_into(key, mask, &mut ss);
                for a in tiers.iter() {
                    let tier = a.isa();
                    prop_assert!(
                        a.search_first(key, mask) == want_first,
                        "first diverged at isa={tier} storm={storm} \
                         (rows={rows} cols={cols} key={key:#x} \
                         mask={mask:#x})"
                    );
                    let got = a.search_into(key, mask, &mut sb);
                    prop_assert!(
                        got == want,
                        "outcome diverged at isa={tier}: {got:?} vs \
                         {want:?} (key={key:#x} mask={mask:#x})"
                    );
                    prop_assert!(
                        sb.match_words() == ss.match_words(),
                        "match flags diverged at isa={tier} \
                         (key={key:#x} mask={mask:#x})"
                    );
                }
            }
            // a batched wave per storm, mixed hit/miss keys and masks
            let n = 1 + g.int(96);
            let keys: Vec<u64> = (0..n)
                .map(|i| {
                    if i % 2 == 0 {
                        g.u64()
                    } else {
                        scalar.read_col(g.int(cols).min(cols - 1))
                    }
                })
                .collect();
            let masks: Vec<u64> = (0..n)
                .map(|i| match i % 4 {
                    0 => !0u64,
                    1 => 0xFFFF,
                    2 => 0,
                    _ => g.u64(),
                })
                .collect();
            let mut out = Vec::new();
            for a in tiers.iter() {
                let tier = a.isa();
                out.clear();
                a.search_many_bitsliced(&keys, &masks, &mut sb, &mut out);
                prop_assert!(out.len() == n, "wave length at isa={tier}");
                for (i, got) in out.iter().enumerate() {
                    prop_assert!(
                        *got == scalar.search_first(keys[i], masks[i]),
                        "wave member {i} diverged at isa={tier} \
                         (key={:#x} mask={:#x})",
                        keys[i],
                        masks[i]
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_region_alloc_free_no_overlap() {
    // The region manager under arbitrary alloc/free interleavings:
    // every live region is 64B-aligned, stays inside its own window,
    // never overlaps another live region; frees of live regions
    // succeed exactly once; CAM capacity never exceeds the limit.
    check("alloc_free_overlap", 40, |g| {
        let cam_limit = 1u64 << (12 + g.int(6));
        let mut a = Allocator::reconfigurable(
            1 << 20,
            1 << 20,
            cam_limit / 4,
            cam_limit,
        );
        let mut live: Vec<Region> = Vec::new();
        for _ in 0..g.int(120) {
            if g.int(3) == 0 && !live.is_empty() {
                let i = g.int(live.len()).min(live.len() - 1);
                let r = live.swap_remove(i);
                prop_assert!(a.free(&r).is_ok(), "free of live {r:?}");
                prop_assert!(a.free(&r).is_err(), "double free of {r:?}");
            } else {
                let size = 1 + g.u64() % 4096;
                let got = match g.int(3) {
                    0 => a.malloc(size),
                    1 => a.flat_ram_malloc(size),
                    _ => a.flat_cam_malloc(size),
                };
                if let Ok(r) = got {
                    prop_assert!(r.size == size, "size mangled");
                    live.push(r);
                }
            }
        }
        for r in &live {
            prop_assert!(r.base % 64 == 0, "unaligned: {r:?}");
            prop_assert!(
                space_of(r.base) == r.space
                    && space_of(r.base + r.size - 1) == r.space,
                "region leaks out of its window: {r:?}"
            );
        }
        for (i, r) in live.iter().enumerate() {
            for r2 in &live[i + 1..] {
                prop_assert!(!r.overlaps(r2), "overlap: {r:?} vs {r2:?}");
            }
        }
        prop_assert!(
            a.cam_capacity() <= cam_limit,
            "cam capacity {} exceeded limit {cam_limit}",
            a.cam_capacity()
        );
        Ok(())
    });
}

#[test]
fn prop_space_of_window_boundaries() {
    // Exact boundary addresses classify into the right window: the
    // last byte below each base, the base itself, the REG_BASE edge
    // and the CAM window top.
    check("space_of_boundaries", 1, |_| {
        let cases = [
            (alloc::DDR_BASE, Space::Ddr),
            (alloc::FLAT_RAM_BASE - 1, Space::Ddr),
            (alloc::FLAT_RAM_BASE, Space::FlatRam),
            (alloc::FLAT_CAM_BASE - 1, Space::FlatRam),
            (alloc::FLAT_CAM_BASE, Space::FlatCam),
            (alloc::REG_BASE - 1, Space::FlatCam),
            (alloc::REG_BASE, Space::Register),
            (alloc::KEY_REG_ADDR, Space::Register),
            (alloc::MASK_REG_ADDR, Space::Register),
            (alloc::MATCH_REG_ADDR, Space::Register),
            (alloc::FLAT_CAM_BASE + (1 << 40) - 1, Space::Register),
        ];
        for (addr, want) in cases {
            prop_assert!(
                space_of(addr) == want,
                "space_of({addr:#x}) != {want:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_hybrid_boundary_migration_preserves_t_mww_locks() {
    // Cross-boundary vault migration on the hybrid MemCache device
    // must carry WearLeveler history: a superset whose t_MWW budget is
    // exhausted in the flat region stays locked after the boundary
    // moves — in the surviving flat leveler AND in the crossing vaults
    // that join the cache — and unlocks only when the window expires.
    // (`repartition_preserves_t_mww_locks` pins the intra-flat analog.)
    use monarch::config::MonarchGeom;
    use monarch::device::AssocDevice;
    use monarch::monarch::MonarchHybrid;
    check("hybrid_boundary_wear_carry", 12, |g: &mut Gen| {
        let geom = MonarchGeom {
            vaults: 4,
            banks_per_vault: 2,
            supersets_per_bank: 2,
            sets_per_superset: 2,
            rows_per_set: 64,
            cols_per_set: 512,
            layers: 1,
        };
        // disable the rotation triggers so only t_MWW state matters
        let wear = WearConfig {
            wc_limit: u64::MAX,
            dc_limit: u64::MAX,
            wr_shift: 63,
            ..WearConfig::default_m(1)
        };
        let window = 1_000_000u64;
        // 1 or 2 cache vaults: a flat region survives both moves
        let from = 1 + g.int(2);
        let mut h = MonarchHybrid::new(geom, from, 4, wear, window, true);
        // exhaust flat superset 0's budget (m=1: 512 block writes);
        // any block with block/sets_per_superset == 0 maps to it
        let mut now = 10u64;
        for i in 0..512u64 {
            let block = g.int(geom.sets_per_superset) as u64;
            prop_assert!(
                h.ram_access(block, true, now).is_some(),
                "write {i} rejected before the budget ran out"
            );
            now += 1;
        }
        prop_assert!(
            h.ram_access(0, true, now).is_none(),
            "superset 0 must lock after 512 writes"
        );
        let locked_now = now;
        // boundary up: one flat vault crosses into the cache region
        let to = from + 1;
        let r = h.set_boundary(to, now);
        prop_assert!(
            r.from_cache_vaults == from && r.to_cache_vaults == to,
            "unexpected boundary report: {r:?}"
        );
        let flat = h.flat().expect("flat region survives the move");
        prop_assert!(
            flat.wear().locked(0, locked_now),
            "flat lock lost across the boundary move"
        );
        prop_assert!(
            !flat.wear().locked(1, locked_now),
            "untouched superset must stay unlocked"
        );
        let cache = h.cache().expect("cache region exists");
        for v in from..to {
            prop_assert!(
                cache.vault_wear(v).locked(0, locked_now),
                "crossing vault {v} did not inherit the lock"
            );
        }
        // boundary back down: the crossing vault returns its history
        h.set_boundary(from, now);
        let flat = h.flat().expect("flat region");
        prop_assert!(
            flat.wear().locked(0, locked_now),
            "lock lost on the return move"
        );
        prop_assert!(
            h.ram_access(0, true, locked_now).is_none(),
            "migrated lock must still block flat-RAM writes"
        );
        // window expiry frees the superset and its budget
        let later = window + 1;
        prop_assert!(
            !h.flat().unwrap().wear().locked(0, later),
            "lock must expire with the window"
        );
        prop_assert!(
            h.ram_access(0, true, later).is_some(),
            "expired window must accept writes again"
        );
        Ok(())
    });
}

#[test]
fn prop_fault_plane_deterministic_across_engines_and_tiers() {
    // The same campaign seed + the same op stream must produce the
    // identical fault set, counters, retired bitmap, and search
    // results no matter which engine evaluates the searches (scalar
    // per-column, bit-sliced, every supported SIMD tier): fault draws
    // are pure functions of (seed, salt, col, row/seq), never of the
    // evaluation order. Worker-count determinism is pinned end-to-end
    // by the fault_tolerance bench and the service differentials.
    // Also pins the core invariant on every step: a checked write
    // either stores exactly the intended word, or the column is
    // retired, zeroed, and never serves a match again.
    check("fault_plane_determinism", 20, |g: &mut Gen| {
        let rows = 1 + g.int(64).min(63);
        let cols = 1 + g.int(300);
        let row_mask =
            if rows == 64 { !0u64 } else { (1u64 << rows) - 1 };
        let mut cfg = FaultConfig {
            seed: g.u64(),
            stuck_per_mille: [0, 5, 50][g.int(3)],
            transient_pct: [0.0, 2.0, 15.0][g.int(3)],
            max_retries: g.int(3) as u32,
            ..FaultConfig::default()
        };
        if !cfg.enabled() {
            cfg.transient_pct = 2.0;
        }
        let n = 40 + g.int(160);
        let ops: Vec<(usize, u64)> = (0..n)
            .map(|_| (g.int(cols).min(cols - 1), g.u64()))
            .collect();
        // every step's observables: the ColWrite outcome, the column
        // image after it, its retired flag, and a whole-array search
        // for the word just written
        type Step = (ColWrite, u64, bool, Option<usize>);
        let run = |scalar: bool, isa: Option<Isa>| -> (
            Vec<Step>,
            [u64; 5],
            Vec<bool>,
        ) {
            let mut a = XamArray::new(rows, cols);
            a.set_fault_plane(&cfg, 3);
            if scalar {
                a.force_scalar(true);
            }
            if let Some(t) = isa {
                a.force_isa(t);
            }
            let steps = ops
                .iter()
                .map(|&(col, word)| {
                    let w = a.write_col_checked(col, word);
                    (
                        w,
                        a.read_col(col),
                        a.is_col_retired(col),
                        a.search_first(word, !0),
                    )
                })
                .collect();
            let p = a.fault_plane().expect("armed plane stays attached");
            (
                steps,
                [
                    p.retired_cols,
                    p.lost_words,
                    p.transient_faults,
                    p.stuck_write_faults,
                    p.retry_writes,
                ],
                (0..cols).map(|c| a.is_col_retired(c)).collect(),
            )
        };
        let base = run(true, None);
        for (i, &(w, img, retired, hit)) in base.0.iter().enumerate() {
            let (col, word) = ops[i];
            if w.stored {
                prop_assert!(
                    img == word & row_mask,
                    "op {i}: stored but col {col} holds {img:#x} not \
                     {:#x}",
                    word & row_mask
                );
            } else {
                prop_assert!(
                    retired && img == 0,
                    "op {i}: unstored col {col} must be retired and \
                     zeroed (retired={retired}, img={img:#x})"
                );
            }
            if let Some(h) = hit {
                prop_assert!(
                    !base.2[h],
                    "op {i}: search returned retired column {h}"
                );
            }
        }
        let replay = run(true, None);
        prop_assert!(
            replay == base,
            "same seed + stream must replay bit-identically"
        );
        let bitsliced = run(false, None);
        prop_assert!(
            bitsliced == base,
            "bit-sliced engine diverged from scalar under faults"
        );
        for tier in Isa::supported_tiers() {
            let tiered = run(false, Some(tier));
            prop_assert!(
                tiered == base,
                "isa={tier} diverged from scalar under faults"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_endurance_remap_invariants() {
    // The retire->remap->degrade escalation at superset granularity:
    // a degraded superset sheds every later write (never silently
    // accepts one), no spare ever serves two supersets at once (each
    // remap consumes a fresh spare from the pool, ids strictly
    // increasing), and the pool is never overdrawn.
    check("endurance_remap", 30, |g: &mut Gen| {
        let ss = 2 + g.int(16);
        let threshold = 20 + g.u64() % 200;
        let spares = g.int(6) as u32;
        let cfg = WearConfig {
            wc_limit: u64::MAX,
            dc_limit: u64::MAX,
            wr_shift: 63,
            ..WearConfig::default_m(4)
        };
        let mut wl = WearLeveler::new(cfg, ss, u64::MAX);
        wl.set_endurance(threshold, spares);
        let mut degraded = vec![false; ss];
        for i in 0..500 + g.int(4000) {
            let s = g.int(ss);
            match wl.endure(s) {
                Endure::Blocked => prop_assert!(
                    degraded[s],
                    "write {i}: blocked a live superset {s}"
                ),
                Endure::JustDegraded => {
                    prop_assert!(
                        !degraded[s],
                        "write {i}: superset {s} degraded twice"
                    );
                    degraded[s] = true;
                }
                Endure::Remapped => prop_assert!(
                    !degraded[s],
                    "write {i}: remapped degraded superset {s}"
                ),
                Endure::Ok => prop_assert!(
                    !degraded[s],
                    "write {i}: degraded superset {s} accepted a write"
                ),
            }
        }
        prop_assert!(
            wl.remap_log.len() as u32 == wl.spares_used(),
            "remap log {} != spares used {}",
            wl.remap_log.len(),
            wl.spares_used()
        );
        prop_assert!(
            wl.spares_used() <= spares,
            "spare pool overdrawn: {} > {spares}",
            wl.spares_used()
        );
        for (i, &(s, id)) in wl.remap_log.iter().enumerate() {
            prop_assert!(
                id == i as u32 + 1,
                "spare id {id} reused or skipped at remap {i}"
            );
            prop_assert!(s < ss, "remap of out-of-range superset {s}");
        }
        for s in 0..ss {
            prop_assert!(
                wl.is_degraded(s) == degraded[s],
                "degraded flag diverged at superset {s}"
            );
            if degraded[s] {
                prop_assert!(
                    wl.endure(s) == Endure::Blocked,
                    "degraded superset {s} accepted a write"
                );
            }
        }
        prop_assert!(
            wl.degraded_count() ==
                degraded.iter().filter(|&&d| d).count() as u64,
            "degraded count disagrees with the model"
        );
        Ok(())
    });
}

#[test]
fn prop_wear_history_survives_endurance_remap() {
    // Remapping a superset onto a fresh spare replaces its cells, not
    // its controller state: the endurance budget resets (new cells)
    // but the t_MWW thermal lock, the global write counter, and window
    // expiry behave exactly as if no remap had happened.
    check("wear_survives_remap", 10, |g: &mut Gen| {
        let cfg = WearConfig {
            wc_limit: u64::MAX,
            dc_limit: u64::MAX,
            wr_shift: 63,
            ..WearConfig::default_m(1)
        };
        let window = 1_000_000u64;
        let mut wl = WearLeveler::new(cfg, 4, window);
        wl.set_endurance(64, 2);
        // exhaust superset 0's t_MWW budget (m=1: 512 block writes)
        let mut now = 1u64;
        for i in 0..512u64 {
            let (ok, _) = wl.on_write(0, g.int(2) == 0, now);
            prop_assert!(ok, "write {i} blocked before the budget ran out");
            now += 1;
        }
        prop_assert!(
            wl.locked(0, now),
            "exhausted budget must lock the window"
        );
        let wc = wl.write_count();
        // now push it over the endurance threshold -> remap to a spare
        let mut remapped = false;
        for _ in 0..64 {
            if wl.endure(0) == Endure::Remapped {
                remapped = true;
                break;
            }
        }
        prop_assert!(remapped, "endurance threshold never crossed");
        prop_assert!(
            wl.cum_writes(0) == 0,
            "endurance budget must reset on the fresh spare"
        );
        prop_assert!(
            wl.locked(0, now),
            "t_MWW lock lost across the endurance remap"
        );
        prop_assert!(
            wl.write_count() == wc,
            "remap must not invent block writes"
        );
        prop_assert!(
            !wl.locked(0, now + window),
            "window expiry must still unlock after the remap"
        );
        Ok(())
    });
}

#[test]
fn prop_wear_leveler_counts_consistent() {
    check("wear_counts", 30, |g: &mut Gen| {
        let ss = 2 + g.int(32);
        let cfg = WearConfig {
            wc_limit: u64::MAX,
            dc_limit: u64::MAX,
            wr_shift: 63,
            ..WearConfig::default_m(4)
        };
        let mut wl = WearLeveler::new(cfg, ss, u64::MAX);
        let mut accepted = 0u64;
        for i in 0..2000u64 {
            let target = g.int(ss);
            let (ok, _) = wl.on_write(target, g.int(2) == 0, i);
            if ok {
                accepted += 1;
            }
        }
        let total: u64 =
            wl.all_intervals().iter().flatten().copied().sum();
        prop_assert!(
            total == accepted,
            "interval snapshots {total} != accepted writes {accepted}"
        );
        Ok(())
    });
}
