//! Differential tests for the service trace layer.
//!
//! The trace codec promises that a captured stream survives encode →
//! decode bit-identically, and the service driver promises that its
//! modeled report is a pure function of (backend, stream). Together
//! they make `monarch serve --replay` reproducible: serving the
//! decoded stream must produce the same modeled-cycle latency report
//! as serving the stream it was captured from, on every registered
//! sharded backend, fingerprint-for-fingerprint.

use monarch::coordinator::{self, Budget};
use monarch::service::gen::{generate, Request, TrafficConfig};
use monarch::service::trace::{
    decode, encode, read_trace, write_trace, TraceMeta,
};

fn captured() -> (TraceMeta, Vec<Request>) {
    let budget = Budget { hash_ops: 900, ..Budget::quick() };
    coordinator::service_traffic(&budget, 2.0)
}

#[test]
fn decoded_stream_is_the_captured_stream() {
    let (meta, reqs) = captured();
    let bytes = encode(&meta, &reqs);
    let (meta2, reqs2) = decode(&bytes).expect("decode own encoding");
    assert_eq!(meta2, meta);
    assert_eq!(reqs2, reqs, "decode must return the captured stream");
    // and the codec is a bijection on its own output
    assert_eq!(encode(&meta2, &reqs2), bytes);
}

#[test]
fn replay_matches_capture_on_every_sharded_backend() {
    let (meta, reqs) = captured();
    let bytes = encode(&meta, &reqs);
    let (dmeta, dreqs) = decode(&bytes).expect("decode own encoding");
    let budget = Budget::quick();
    for shards in [1usize, 2, 4, 8] {
        let live = coordinator::service_replay(&budget, shards, &meta, &reqs);
        let replay =
            coordinator::service_replay(&budget, shards, &dmeta, &dreqs);
        assert_eq!(
            live.modeled_fingerprint(),
            replay.modeled_fingerprint(),
            "S={shards}: replaying the decoded trace diverged"
        );
        assert_eq!(live.cycles, replay.cycles);
        assert_eq!(live.completed_ops, replay.completed_ops);
        assert!(live.completed_ops > 0, "S={shards}: nothing served");
    }
}

#[test]
fn replay_is_stable_across_runs() {
    let (meta, reqs) = captured();
    let a = coordinator::service_replay(&Budget::quick(), 4, &meta, &reqs);
    let b = coordinator::service_replay(&Budget::quick(), 4, &meta, &reqs);
    assert_eq!(a.modeled_fingerprint(), b.modeled_fingerprint());
}

#[test]
fn trace_file_roundtrip() {
    let (meta, reqs) = captured();
    let path = std::env::temp_dir().join("monarch_service_replay_test.trace");
    let path = path.to_str().expect("utf-8 temp path");
    write_trace(path, &meta, &reqs).expect("write trace");
    let (meta2, reqs2) = read_trace(path).expect("read trace back");
    let _ = std::fs::remove_file(path);
    assert_eq!(meta2, meta);
    assert_eq!(reqs2, reqs);
}

#[test]
fn generation_is_deterministic_per_config() {
    let cfg = TrafficConfig { ops: 600, ..TrafficConfig::default() };
    assert_eq!(generate(&cfg), generate(&cfg));
    let reseeded = TrafficConfig { seed: cfg.seed ^ 1, ..cfg };
    assert_ne!(
        generate(&cfg),
        generate(&reseeded),
        "a different seed must produce a different stream"
    );
}
