//! Differential tests for the service trace layer.
//!
//! The trace codec promises that a captured stream survives encode →
//! decode bit-identically, and the service driver promises that its
//! modeled report is a pure function of (backend, stream). Together
//! they make `monarch serve --replay` reproducible: serving the
//! decoded stream must produce the same modeled-cycle latency report
//! as serving the stream it was captured from, on every registered
//! sharded backend, fingerprint-for-fingerprint.

use monarch::coordinator::{self, Budget};
use monarch::service::gen::{generate, Class, Op, Request, TrafficConfig};
use monarch::service::trace::{
    decode, encode, read_trace, write_trace, TraceMeta,
};
use monarch::util::pool::with_workers;

fn captured() -> (TraceMeta, Vec<Request>) {
    let budget = Budget { hash_ops: 900, ..Budget::quick() };
    coordinator::service_traffic(&budget, 2.0)
}

#[test]
fn decoded_stream_is_the_captured_stream() {
    let (meta, reqs) = captured();
    // the capture must exercise the whole MONSRV02 record vocabulary,
    // or this round-trip proves less than it claims
    assert!(reqs.iter().any(|r| r.op == Op::Insert), "no inserts");
    assert!(reqs.iter().any(|r| r.op == Op::Delete), "no deletes");
    assert!(reqs.iter().any(|r| r.slo > 0), "no SLO-carrying requests");
    let bytes = encode(&meta, &reqs);
    let (meta2, reqs2) = decode(&bytes).expect("decode own encoding");
    assert_eq!(meta2, meta);
    assert_eq!(reqs2, reqs, "decode must return the captured stream");
    // and the codec is a bijection on its own output
    assert_eq!(encode(&meta2, &reqs2), bytes);
}

#[test]
fn committed_v1_fixture_decodes_byte_exact() {
    // a MONSRV01 capture committed before the format grew mutations:
    // decoding it must keep producing exactly these requests (lookups,
    // no SLO, phases shifted past the new warm slot)
    let bytes = include_bytes!("data/monsrv01.trace");
    let (meta, reqs) = decode(bytes).expect("v1 fixture must decode");
    assert_eq!(
        meta,
        TraceMeta { population: 256, num_sets: 128, seed: 7 }
    );
    let want = [
        (100u64, 0x1111u64, 17u64, 8u32, Class::Interactive, 1u8),
        (250, 0x2222, 42, 127, Class::Bulk, 2),
        (400, 0x3333, 7, 0, Class::Interactive, 3),
        (650, 0x4444, 99, 64, Class::Bulk, 1),
    ];
    assert_eq!(reqs.len(), want.len());
    for (r, &(arrive, key, vb, set, class, phase)) in reqs.iter().zip(&want) {
        assert_eq!(r.arrive, arrive);
        assert_eq!(r.key, key);
        assert_eq!(r.value_block, vb);
        assert_eq!(r.set, set);
        assert_eq!(r.class, class);
        assert_eq!(r.phase, phase, "v1 phases shift by the warm slot");
        assert_eq!(r.op, Op::Lookup, "v1 records are lookups");
        assert_eq!(r.slo, 0, "v1 records carry no SLO");
    }
    // upgrading the fixture to v2 is lossless from here on
    let v2 = encode(&meta, &reqs);
    let (meta2, reqs2) = decode(&v2).expect("decode upgraded fixture");
    assert_eq!(meta2, meta);
    assert_eq!(reqs2, reqs);
}

#[test]
fn fingerprint_is_identical_across_worker_counts() {
    // the MONARCH_THREADS contract: the parallel dispatch loop may
    // change wall-clock, never the modeled report
    let (meta, reqs) = captured();
    let budget = Budget::quick();
    let fps: Vec<String> = [1usize, 2, 8]
        .into_iter()
        .map(|w| {
            let r = with_workers(w, || {
                coordinator::service_replay(&budget, 8, &meta, &reqs)
            });
            assert!(r.completed_ops > 0, "{w} workers: nothing served");
            r.modeled_fingerprint()
        })
        .collect();
    assert_eq!(fps[0], fps[1], "2 workers diverged from serial");
    assert_eq!(fps[0], fps[2], "8 workers diverged from serial");
}

#[test]
fn replay_matches_capture_on_every_sharded_backend() {
    let (meta, reqs) = captured();
    let bytes = encode(&meta, &reqs);
    let (dmeta, dreqs) = decode(&bytes).expect("decode own encoding");
    let budget = Budget::quick();
    for shards in [1usize, 2, 4, 8] {
        let live = coordinator::service_replay(&budget, shards, &meta, &reqs);
        let replay =
            coordinator::service_replay(&budget, shards, &dmeta, &dreqs);
        assert_eq!(
            live.modeled_fingerprint(),
            replay.modeled_fingerprint(),
            "S={shards}: replaying the decoded trace diverged"
        );
        assert_eq!(live.cycles, replay.cycles);
        assert_eq!(live.completed_ops, replay.completed_ops);
        assert!(live.completed_ops > 0, "S={shards}: nothing served");
    }
}

#[test]
fn replay_is_stable_across_runs() {
    let (meta, reqs) = captured();
    let a = coordinator::service_replay(&Budget::quick(), 4, &meta, &reqs);
    let b = coordinator::service_replay(&Budget::quick(), 4, &meta, &reqs);
    assert_eq!(a.modeled_fingerprint(), b.modeled_fingerprint());
}

#[test]
fn trace_file_roundtrip() {
    let (meta, reqs) = captured();
    let path = std::env::temp_dir().join("monarch_service_replay_test.trace");
    let path = path.to_str().expect("utf-8 temp path");
    write_trace(path, &meta, &reqs).expect("write trace");
    let (meta2, reqs2) = read_trace(path).expect("read trace back");
    let _ = std::fs::remove_file(path);
    assert_eq!(meta2, meta);
    assert_eq!(reqs2, reqs);
}

#[test]
fn generation_is_deterministic_per_config() {
    let cfg = TrafficConfig { ops: 600, ..TrafficConfig::default() };
    assert_eq!(generate(&cfg), generate(&cfg));
    let reseeded = TrafficConfig { seed: cfg.seed ^ 1, ..cfg };
    assert_ne!(
        generate(&cfg),
        generate(&reseeded),
        "a different seed must produce a different stream"
    );
}
