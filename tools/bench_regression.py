#!/usr/bin/env python3
"""Bench snapshot regression gate (stdlib only).

Four modes, all exiting non-zero on failure:

  --service  SNAPSHOT FRESH   modeled serve throughput per (system, load)
                              must stay within TOLERANCE of the snapshot
  --xamsearch SNAPSHOT FRESH  engine speedup ratios vs the scalar engine
                              per workload must stay within TOLERANCE
                              (ratios, never absolute host ops/sec — the
                              snapshot machine is not the CI machine)
  --memcache SNAPSHOT FRESH   hybrid MemCache total cycles per
                              (workload, cache_vaults) must stay within
                              TOLERANCE, and some strict hybrid split
                              must still beat both extremes
  --replay-check JSON...      every file's summary rows must carry the
                              same modeled_fingerprint (the trace
                              record -> replay acceptance gate)

Snapshots are committed at the repository root and refreshed by copying
a CI BENCH_* artifact over them. A snapshot marked "bootstrap": true
(or with no rows) passes with a notice — that is how the gate is armed
before the first artifact lands: the comparison logic still runs on
every CI build, it just has nothing trusted to compare against yet.
"""

import json
import sys

TOLERANCE = 0.20  # fail when fresh < snapshot * (1 - TOLERANCE)


def fail(msg):
    print(f"bench_regression: FAIL: {msg}")
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except ValueError as e:
        fail(f"{path} is not valid JSON: {e}")
    if "schema_version" not in doc:
        fail(f"{path}: missing schema_version (pre-envelope emitter?)")
    return doc


def is_bootstrap(doc, path):
    if doc.get("bootstrap") or not doc.get("rows"):
        print(
            f"bench_regression: NOTICE: {path} is a bootstrap snapshot "
            "(no trusted numbers yet); refresh it from a CI BENCH_* "
            "artifact to arm the gate."
        )
        return True
    return False


def summaries(doc):
    """serve envelopes carry summary + cell rows; keep the summaries."""
    return [r for r in doc["rows"] if r.get("row") == "summary"]


def check_service(snap_path, fresh_path):
    snap, fresh = load(snap_path), load(fresh_path)
    fresh_by_key = {
        (r["system"], r["load"]): r for r in summaries(fresh)
    }
    if not fresh_by_key:
        fail(f"{fresh_path}: no summary rows")
    if is_bootstrap(snap, snap_path):
        return
    compared = 0
    for r in summaries(snap):
        key = (r["system"], r["load"])
        cur = fresh_by_key.get(key)
        if cur is None:
            fail(f"{fresh_path}: sweep cell {key} disappeared")
        old, new = r["ops_per_kcycle"], cur["ops_per_kcycle"]
        if new < old * (1.0 - TOLERANCE):
            fail(
                f"serve {key}: ops/kcycle {new:.3f} regressed >"
                f"{TOLERANCE:.0%} below snapshot {old:.3f}"
            )
        compared += 1
    print(f"bench_regression: service OK ({compared} cells within "
          f"{TOLERANCE:.0%} of snapshot)")


def speedups(doc, path):
    """xamsearch rows -> {(engine, workload): ops_per_sec / scalar}."""
    by_key = {(r["engine"], r["workload"]): r["ops_per_sec"]
              for r in doc["rows"]}
    out = {}
    for (engine, wl), ops in by_key.items():
        if engine == "scalar":
            continue
        base = by_key.get(("scalar", wl))
        if not base:
            fail(f"{path}: no scalar baseline for workload {wl!r}")
        out[(engine, wl)] = ops / base
    return out


def check_xamsearch(snap_path, fresh_path):
    snap, fresh = load(snap_path), load(fresh_path)
    fresh_ratios = speedups(fresh, fresh_path)
    if not fresh_ratios:
        fail(f"{fresh_path}: no non-scalar engine rows")
    if is_bootstrap(snap, snap_path):
        return
    compared = 0
    for key, old in speedups(snap, snap_path).items():
        new = fresh_ratios.get(key)
        if new is None:
            fail(f"{fresh_path}: engine cell {key} disappeared")
        if new < old * (1.0 - TOLERANCE):
            fail(
                f"xamsearch {key}: speedup {new:.2f}x regressed >"
                f"{TOLERANCE:.0%} below snapshot {old:.2f}x"
            )
        compared += 1
    print(f"bench_regression: xamsearch OK ({compared} speedup ratios "
          f"within {TOLERANCE:.0%} of snapshot)")


def hybrid_beats_extremes(doc, path):
    """The memcache acceptance gate: on some workload a strict split
    (0 < cache_vaults < total) wins on total cycles over BOTH extremes."""
    by_wl = {}
    for r in doc["rows"]:
        by_wl.setdefault(r["workload"], []).append(r)
    for wl, rows in by_wl.items():
        def best(pred):
            sel = [r["total_cycles"] for r in rows if pred(r)]
            return min(sel) if sel else None
        cache = best(lambda r: r["cache_vaults"] == r["total_vaults"])
        mem = best(lambda r: r["cache_vaults"] == 0)
        hybrid = best(lambda r: 0 < r["cache_vaults"] < r["total_vaults"])
        if None in (cache, mem, hybrid):
            fail(f"{path}: workload {wl!r} is missing a split class")
        if hybrid < cache and hybrid < mem:
            return True
    return False


def check_memcache(snap_path, fresh_path):
    snap, fresh = load(snap_path), load(fresh_path)
    if not fresh.get("rows"):
        fail(f"{fresh_path}: no rows")
    if not hybrid_beats_extremes(fresh, fresh_path):
        fail(
            f"{fresh_path}: no strict hybrid split beats both the "
            "all-cache and all-memory extremes on any workload"
        )
    if is_bootstrap(snap, snap_path):
        return
    fresh_by_key = {
        (r["workload"], r["cache_vaults"]): r for r in fresh["rows"]
    }
    compared = 0
    for r in snap["rows"]:
        key = (r["workload"], r["cache_vaults"])
        cur = fresh_by_key.get(key)
        if cur is None:
            fail(f"{fresh_path}: sweep cell {key} disappeared")
        # cycles are a cost: regression means the total going UP
        old, new = r["total_cycles"], cur["total_cycles"]
        if new > old * (1.0 + TOLERANCE):
            fail(
                f"memcache {key}: total cycles {new} regressed >"
                f"{TOLERANCE:.0%} above snapshot {old}"
            )
        compared += 1
    print(f"bench_regression: memcache OK ({compared} cells within "
          f"{TOLERANCE:.0%} of snapshot, hybrid beats both extremes)")


def check_replay(paths):
    if len(paths) < 2:
        fail("--replay-check needs at least two serve envelopes")
    per_file = []
    for path in paths:
        rows = summaries(load(path))
        if not rows:
            fail(f"{path}: no summary rows")
        by_system = {}
        for r in rows:
            fp = r.get("modeled_fingerprint")
            if not fp:
                fail(f"{path}: summary row without modeled_fingerprint")
            by_system[r["system"]] = fp
        per_file.append((path, by_system))
    base_path, base = per_file[0]
    for path, cur in per_file[1:]:
        if set(cur) != set(base):
            fail(f"{path}: systems {sorted(cur)} != {sorted(base)}")
        for system, fp in cur.items():
            if fp != base[system]:
                fail(
                    f"replay fingerprint diverged for {system}: "
                    f"{base_path}={base[system]} vs {path}={fp}"
                )
    print(
        f"bench_regression: replay OK ({len(per_file)} envelopes agree "
        f"on {len(base)} fingerprint(s))"
    )


def main(argv):
    if len(argv) >= 4 and argv[1] == "--service":
        check_service(argv[2], argv[3])
    elif len(argv) >= 4 and argv[1] == "--xamsearch":
        check_xamsearch(argv[2], argv[3])
    elif len(argv) >= 4 and argv[1] == "--memcache":
        check_memcache(argv[2], argv[3])
    elif len(argv) >= 2 and argv[1] == "--replay-check":
        check_replay(argv[2:])
    else:
        fail(
            "usage: bench_regression.py --service SNAPSHOT FRESH | "
            "--xamsearch SNAPSHOT FRESH | --memcache SNAPSHOT FRESH | "
            "--replay-check JSON JSON..."
        )


if __name__ == "__main__":
    main(sys.argv)
