#!/usr/bin/env python3
"""Bench snapshot regression gate (stdlib only).

Five modes, all exiting non-zero on failure:

  --service  SNAPSHOT FRESH   modeled serve throughput per (system, load)
                              must stay within TOLERANCE of the snapshot
  --xamsearch SNAPSHOT FRESH  engine speedup ratios vs the scalar engine
                              per (engine, isa, workload) must stay
                              within TOLERANCE (ratios, never absolute
                              host ops/sec — the snapshot machine is not
                              the CI machine)
  --memcache SNAPSHOT FRESH   hybrid MemCache total cycles per
                              (workload, cache_vaults) must stay within
                              TOLERANCE, and some strict hybrid split
                              must still beat both extremes
  --scaling FRESH             the service thread-scaling envelope:
                              every worker count shares one modeled
                              fingerprint and the million-key ingest
                              planted >= 90% of its population
  --replay-check JSON...      every file's summary rows must carry the
                              same modeled_fingerprint (the trace
                              record -> replay acceptance gate)
  --faults SNAPSHOT FRESH [SERVE]
                              the fault-injection sweep: the zero-fault
                              campaign must report zero damage (and,
                              when a fresh serve envelope is given,
                              fingerprint-match its load-1.0 cell on
                              the same system), hits must degrade
                              monotonically as campaigns escalate, and
                              every campaign must survive above the
                              snapshot's survival floor
  --selftest                  run the gate against synthetic envelopes
                              in a temp dir (exercises the failure
                              diagnostics end-to-end; used by CI)

Snapshots are committed at the repository root. Two armed shapes:

  "mode": "floors"   machine-portable minimums: xamsearch snapshots
                     carry a "floors" list of {engine, workload,
                     vs?, min_ratio, needs_simd?} rows checked against
                     the fresh speedup ratios (ratios survive machine
                     changes; absolute ops/sec do not); service and
                     memcache snapshots carry "min_cells" plus
                     shape/sanity requirements on every fresh row.
                     This is how the gate ships armed without a
                     trusted same-machine artifact.
  full rows          a copied CI BENCH_* artifact: per-cell drift
                     comparison within TOLERANCE (tightest gate, but
                     only trustworthy against the same runner class).

A snapshot marked "bootstrap": true (or with no rows AND no floors
mode) passes with a notice — the disarmed bootstrap shape older
revisions shipped.
"""

import json
import sys

TOLERANCE = 0.20  # fail when fresh < snapshot * (1 - TOLERANCE)


def fail(msg):
    print(f"bench_regression: FAIL: {msg}")
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except ValueError as e:
        fail(f"{path} is not valid JSON (truncated emit?): {e}")
    if not isinstance(doc, dict):
        fail(f"{path}: top level is {type(doc).__name__}, expected the "
             "JSON envelope {schema_version, experiment, rows: [...]}")
    if "schema_version" not in doc:
        fail(f"{path}: missing schema_version (pre-envelope emitter?)")
    return doc


def rows_of(doc, path):
    """The envelope's rows list, with a diagnostic instead of a
    KeyError traceback when an emitter shipped a malformed document."""
    rows = doc.get("rows")
    if not isinstance(rows, list):
        fail(f"{path}: missing 'rows' list — expected the JSON envelope "
             "{schema_version, experiment, rows: [...]}")
    return rows


def is_bootstrap(doc, path):
    if doc.get("bootstrap") or not doc.get("rows"):
        print(
            f"bench_regression: NOTICE: {path} is a bootstrap snapshot "
            "(no trusted numbers yet); refresh it from a CI BENCH_* "
            "artifact (or switch it to floors mode) to arm the gate."
        )
        return True
    return False


def summaries(doc, path):
    """serve envelopes carry summary + cell rows; keep the summaries."""
    return [r for r in rows_of(doc, path) if r.get("row") == "summary"]


def check_service_floors(snap, fresh, snap_path, fresh_path):
    rows = summaries(fresh, fresh_path)
    need = snap.get("min_cells", 1)
    if len(rows) < need:
        fail(
            f"{fresh_path}: {len(rows)} summary cells < floor of "
            f"{need} (sweep shrank?)"
        )
    required = snap.get("require_summary_fields", [])
    for r in rows:
        key = (r.get("system"), r.get("load"))
        if not r.get("ops_per_kcycle", 0) > 0:
            fail(f"{fresh_path}: cell {key} has no modeled throughput")
        if not r.get("modeled_fingerprint"):
            fail(f"{fresh_path}: cell {key} lost its modeled_fingerprint")
        for field in required:
            if not r.get(field, 0) > 0:
                fail(
                    f"{fresh_path}: cell {key} has no positive "
                    f"{field!r} (emitter schema shrank?)"
                )
    print(
        f"bench_regression: service OK ({len(rows)} cells >= floor of "
        f"{need}, all with throughput + fingerprint"
        + (f" + {len(required)} required fields)" if required else ")")
    )


def check_service(snap_path, fresh_path):
    snap, fresh = load(snap_path), load(fresh_path)
    fresh_by_key = {
        (r["system"], r["load"]): r for r in summaries(fresh, fresh_path)
    }
    if not fresh_by_key:
        fail(f"{fresh_path}: no summary rows")
    if snap.get("mode") == "floors":
        return check_service_floors(snap, fresh, snap_path, fresh_path)
    if is_bootstrap(snap, snap_path):
        return
    compared = 0
    for r in summaries(snap, snap_path):
        key = (r["system"], r["load"])
        cur = fresh_by_key.get(key)
        if cur is None:
            fail(f"{fresh_path}: sweep cell {key} disappeared")
        old, new = r["ops_per_kcycle"], cur["ops_per_kcycle"]
        if new < old * (1.0 - TOLERANCE):
            fail(
                f"serve {key}: ops/kcycle {new:.3f} regressed >"
                f"{TOLERANCE:.0%} below snapshot {old:.3f}"
            )
        compared += 1
    print(f"bench_regression: service OK ({compared} cells within "
          f"{TOLERANCE:.0%} of snapshot)")


def xam_cells(doc, path):
    """xamsearch rows -> {(engine, workload): (ops_per_sec, isa)}."""
    out = {}
    for r in rows_of(doc, path):
        out[(r["engine"], r["workload"])] = (
            r["ops_per_sec"],
            r.get("isa", "scalar"),
        )
    if not out:
        fail(f"{path}: no xamsearch rows")
    return out


def speedups(doc, path):
    """{(engine, isa, workload): ops_per_sec / scalar} for the drift
    compare — keyed per ISA tier so a snapshot taken at avx2 is never
    compared against a run forced down to sse2/scalar."""
    cells = xam_cells(doc, path)
    out = {}
    for (engine, wl), (ops, isa) in cells.items():
        if engine == "scalar":
            continue
        base = cells.get(("scalar", wl))
        if not base:
            fail(f"{path}: no scalar baseline for workload {wl!r}")
        out[(engine, isa, wl)] = ops / base[0]
    return out


def check_xamsearch_floors(snap, fresh, snap_path, fresh_path):
    cells = xam_cells(fresh, fresh_path)
    checked, skipped = 0, 0
    floors = snap.get("floors", [])
    if not floors:
        fail(f"{snap_path}: floors mode without a floors list")
    for fl in floors:
        engine, wl = fl["engine"], fl["workload"]
        vs = fl.get("vs", "scalar")
        cell = cells.get((engine, wl))
        base = cells.get((vs, wl))
        if cell is None:
            fail(f"{fresh_path}: floor cell ({engine}, {wl}) missing")
        if base is None:
            fail(f"{fresh_path}: floor baseline ({vs}, {wl}) missing")
        if fl.get("needs_simd") and cell[1] == "scalar":
            # forced-scalar leg or non-SIMD host: the SIMD-over-scalar
            # margin legitimately does not exist there
            skipped += 1
            continue
        ratio = cell[0] / base[0]
        if ratio < fl["min_ratio"]:
            fail(
                f"xamsearch floor: {engine} vs {vs} on {wl} is "
                f"{ratio:.2f}x < required {fl['min_ratio']}x "
                f"(isa={cell[1]})"
            )
        checked += 1
    if checked == 0:
        fail(f"{snap_path}: no applicable floors were checked")
    note = f", {skipped} SIMD-only skipped" if skipped else ""
    print(
        f"bench_regression: xamsearch OK ({checked} speedup floors "
        f"held{note})"
    )


def check_xamsearch(snap_path, fresh_path):
    snap, fresh = load(snap_path), load(fresh_path)
    fresh_ratios = speedups(fresh, fresh_path)
    if not fresh_ratios:
        fail(f"{fresh_path}: no non-scalar engine rows")
    if snap.get("mode") == "floors":
        return check_xamsearch_floors(snap, fresh, snap_path, fresh_path)
    if is_bootstrap(snap, snap_path):
        return
    compared = 0
    for key, old in speedups(snap, snap_path).items():
        new = fresh_ratios.get(key)
        if new is None:
            fail(f"{fresh_path}: engine cell {key} disappeared")
        if new < old * (1.0 - TOLERANCE):
            fail(
                f"xamsearch {key}: speedup {new:.2f}x regressed >"
                f"{TOLERANCE:.0%} below snapshot {old:.2f}x"
            )
        compared += 1
    print(f"bench_regression: xamsearch OK ({compared} speedup ratios "
          f"within {TOLERANCE:.0%} of snapshot)")


def hybrid_beats_extremes(doc, path):
    """The memcache acceptance gate: on some workload a strict split
    (0 < cache_vaults < total) wins on total cycles over BOTH extremes."""
    by_wl = {}
    for r in rows_of(doc, path):
        by_wl.setdefault(r["workload"], []).append(r)
    for wl, rows in by_wl.items():
        def best(pred):
            sel = [r["total_cycles"] for r in rows if pred(r)]
            return min(sel) if sel else None
        cache = best(lambda r: r["cache_vaults"] == r["total_vaults"])
        mem = best(lambda r: r["cache_vaults"] == 0)
        hybrid = best(lambda r: 0 < r["cache_vaults"] < r["total_vaults"])
        if None in (cache, mem, hybrid):
            fail(f"{path}: workload {wl!r} is missing a split class")
        if hybrid < cache and hybrid < mem:
            return True
    return False


def check_memcache(snap_path, fresh_path):
    snap, fresh = load(snap_path), load(fresh_path)
    if not fresh.get("rows"):
        fail(f"{fresh_path}: no rows")
    if not hybrid_beats_extremes(fresh, fresh_path):
        fail(
            f"{fresh_path}: no strict hybrid split beats both the "
            "all-cache and all-memory extremes on any workload"
        )
    if snap.get("mode") == "floors":
        need = snap.get("min_cells", 1)
        rows = rows_of(fresh, fresh_path)
        if len(rows) < need:
            fail(
                f"{fresh_path}: {len(rows)} sweep cells < floor of "
                f"{need} (sweep shrank?)"
            )
        for r in rows:
            key = (r.get("workload"), r.get("cache_vaults"))
            if not r.get("total_cycles", 0) > 0:
                fail(f"{fresh_path}: cell {key} has no modeled cycles")
        print(
            f"bench_regression: memcache OK ({len(rows)} cells >= "
            f"floor of {need}, hybrid beats both extremes)"
        )
        return
    if is_bootstrap(snap, snap_path):
        return
    fresh_by_key = {
        (r["workload"], r["cache_vaults"]): r
        for r in rows_of(fresh, fresh_path)
    }
    compared = 0
    for r in rows_of(snap, snap_path):
        key = (r["workload"], r["cache_vaults"])
        cur = fresh_by_key.get(key)
        if cur is None:
            fail(f"{fresh_path}: sweep cell {key} disappeared")
        # cycles are a cost: regression means the total going UP
        old, new = r["total_cycles"], cur["total_cycles"]
        if new > old * (1.0 + TOLERANCE):
            fail(
                f"memcache {key}: total cycles {new} regressed >"
                f"{TOLERANCE:.0%} above snapshot {old}"
            )
        compared += 1
    print(f"bench_regression: memcache OK ({compared} cells within "
          f"{TOLERANCE:.0%} of snapshot, hybrid beats both extremes)")


def check_replay(paths):
    if len(paths) < 2:
        fail("--replay-check needs at least two serve envelopes")
    per_file = []
    for path in paths:
        rows = summaries(load(path), path)
        if not rows:
            fail(f"{path}: no summary rows")
        by_system = {}
        for r in rows:
            fp = r.get("modeled_fingerprint")
            if not fp:
                fail(f"{path}: summary row without modeled_fingerprint")
            by_system[r["system"]] = fp
        per_file.append((path, by_system))
    base_path, base = per_file[0]
    for path, cur in per_file[1:]:
        if set(cur) != set(base):
            fail(f"{path}: systems {sorted(cur)} != {sorted(base)}")
        for system, fp in cur.items():
            if fp != base[system]:
                fail(
                    f"replay fingerprint diverged for {system}: "
                    f"{base_path}={base[system]} vs {path}={fp}"
                )
    print(
        f"bench_regression: replay OK ({len(per_file)} envelopes agree "
        f"on {len(base)} fingerprint(s))"
    )


def check_scaling(fresh_path):
    """BENCH_service_scaling.json: the thread-scaling envelope the
    service_tail bench emits. Machine-portable gates only — the bench
    itself already gated throughput monotonicity on its own host:
    every scaling row must share one modeled fingerprint (worker count
    cannot change the model), worker counts must be distinct with
    positive host throughput, and the million-key row must have planted
    >= 90% of its population."""
    fresh = load(fresh_path)
    rows = fresh.get("rows", [])
    scaling = [r for r in rows if r.get("row") == "scaling"]
    million = [r for r in rows if r.get("row") == "million"]
    if len(scaling) < 2:
        fail(f"{fresh_path}: wants >=2 scaling rows, got {len(scaling)}")
    fps = {r.get("modeled_fingerprint") for r in scaling}
    if len(fps) != 1 or not fps.pop():
        fail(
            f"{fresh_path}: scaling rows disagree on the modeled "
            f"fingerprint across worker counts"
        )
    workers = [r.get("workers") for r in scaling]
    if len(set(workers)) != len(workers):
        fail(f"{fresh_path}: duplicate worker counts {workers}")
    for r in scaling:
        if not r.get("host_ops_per_sec", 0) > 0:
            fail(
                f"{fresh_path}: workers={r.get('workers')} has no "
                f"host throughput"
            )
    if len(million) != 1:
        fail(f"{fresh_path}: wants 1 million-key row, got {len(million)}")
    m = million[0]
    pop, planted = m.get("population", 0), m.get("planted", 0)
    if pop < 1_000_000:
        fail(f"{fresh_path}: million-key row population is {pop}")
    if planted < pop * 0.9:
        fail(f"{fresh_path}: only {planted} of {pop} keys planted")
    print(
        f"bench_regression: scaling OK ({len(scaling)} worker counts "
        f"share one fingerprint; million-key planted {planted}/{pop})"
    )


SURVIVAL_FLOOR_DEFAULT = 0.5


def fault_campaigns(doc, path):
    rows = [r for r in rows_of(doc, path) if r.get("row") == "campaign"]
    if len(rows) < 2:
        fail(
            f"{path}: {len(rows)} campaign rows (expected the escalating "
            "sweep that `monarch faults` / the fault_tolerance bench "
            "emits)"
        )
    return rows


def check_faults(snap_path, fresh_path, serve_path=None):
    """BENCH_faults.json: graceful degradation under injected faults.

    Machine-portable gates only (the model is deterministic, the host
    is not): the zero-fault campaign must report zero damage and — when
    a fresh serve envelope is supplied — fingerprint-match the serve
    sweep's load-1.0 cell on the same system, proving the fault
    machinery is zero-cost when disabled; every campaign serves the
    identical offered stream and survives above the snapshot's floor;
    hits degrade monotonically as campaigns escalate (1% slack for the
    retry-ladder reshuffle of the transient draw stream); and the
    heaviest campaign visibly retires columns."""
    snap, fresh = load(snap_path), load(fresh_path)
    rows = fault_campaigns(fresh, fresh_path)
    if snap.get("mode") == "floors":
        need = snap.get("min_cells", 2)
        if len(rows) < need:
            fail(
                f"{fresh_path}: {len(rows)} campaign rows < floor of "
                f"{need} (sweep shrank?)"
            )
    else:
        is_bootstrap(snap, snap_path)
    floor = snap.get("survival_floor", SURVIVAL_FLOOR_DEFAULT)
    first = rows[0]
    if first.get("campaign") != "none":
        fail(
            f"{fresh_path}: first campaign is {first.get('campaign')!r}, "
            "expected the fault-free 'none' row"
        )
    for field in (
        "retired_columns", "lost_words", "degraded_sets",
        "transient_faults", "stuck_write_faults", "spares_used",
    ):
        if first.get(field, 0) != 0:
            fail(
                f"{fresh_path}: zero-fault campaign reports "
                f"{field}={first.get(field)} (fault plane armed while "
                "disabled?)"
            )
    if not first.get("modeled_fingerprint"):
        fail(f"{fresh_path}: zero-fault campaign lost its "
             "modeled_fingerprint")
    offered = first.get("offered_ops", 0)
    if not offered > 0:
        fail(f"{fresh_path}: zero-fault campaign offered no ops")
    slack = offered // 100 + 2
    prev_hits = None
    for r in rows:
        label = r.get("campaign")
        if r.get("offered_ops") != offered:
            fail(
                f"{fresh_path}: campaign {label!r} offered "
                f"{r.get('offered_ops')} ops != {offered} (campaigns "
                "must share one deterministic stream)"
            )
        done = r.get("completed_ops", 0)
        if not 0 < done <= offered:
            fail(
                f"{fresh_path}: campaign {label!r} completed {done} of "
                f"{offered} offered ops"
            )
        if r.get("survival", 0.0) < floor:
            fail(
                f"faults {label!r}: survival {r.get('survival', 0.0):.3f} "
                f"under the floor {floor}"
            )
        hits = r.get("hits", 0)
        if prev_hits is not None and hits > prev_hits + slack:
            fail(
                f"faults {label!r}: hits rose to {hits} from {prev_hits} "
                "as the campaign escalated (degradation must be "
                "monotone)"
            )
        prev_hits = hits
    last = rows[-1]
    if not last.get("retired_columns", 0) > 0:
        fail(
            f"{fresh_path}: heaviest campaign {last.get('campaign')!r} "
            "retired no columns — injection is not reaching the write "
            "path"
        )
    if serve_path:
        serve = load(serve_path)
        system = first.get("system")
        fp = first.get("modeled_fingerprint")
        cell = next(
            (
                r for r in summaries(serve, serve_path)
                if r.get("system") == system and r.get("load") == 1.0
            ),
            None,
        )
        if cell is None:
            fail(
                f"{serve_path}: no load-1.0 summary cell for {system!r} "
                "to pin the zero-fault fingerprint against"
            )
        if cell.get("modeled_fingerprint") != fp:
            fail(
                f"zero-fault fingerprint {fp} != serve sweep "
                f"{system!r}@load-1.0 fingerprint "
                f"{cell.get('modeled_fingerprint')} — an armed-but-"
                "disabled fault plane changed the model"
            )
    pin = " + serve fingerprint pin" if serve_path else ""
    print(
        f"bench_regression: faults OK ({len(rows)} campaigns survive "
        f">= {floor}, hits monotone, zero-fault row clean{pin})"
    )


def selftest():
    """Exercise the gate end-to-end against synthetic envelopes: each
    failure diagnostic is produced by an actual subprocess invocation
    of this script, so the selftest covers argv parsing, load(), and
    the check bodies exactly as CI runs them."""
    import os
    import subprocess
    import tempfile

    me = os.path.abspath(__file__)

    def run(*args):
        p = subprocess.run(
            [sys.executable, me, *args],
            stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT,
            text=True,
        )
        return p.returncode, p.stdout

    def expect(name, code, out, want_code, needle):
        if code != want_code:
            fail(
                f"selftest {name}: exit {code}, wanted {want_code}; "
                f"output:\n{out}"
            )
        if needle not in out:
            fail(
                f"selftest {name}: output is missing {needle!r}; "
                f"output:\n{out}"
            )
        print(f"bench_regression: selftest case OK: {name}")

    def campaign(label, hits, retired, survival):
        return {
            "row": "campaign",
            "campaign": label,
            "system": "Monarch(S=8)",
            "offered_ops": 1000,
            "completed_ops": int(1000 * survival),
            "survival": survival,
            "hits": hits,
            "retired_columns": retired,
            "lost_words": retired,
            "degraded_sets": 0,
            "transient_faults": retired,
            "stuck_write_faults": retired,
            "spares_used": 0,
            "modeled_fingerprint": f"fp-{label}",
        }

    with tempfile.TemporaryDirectory() as td:

        def write(name, doc):
            path = os.path.join(td, name)
            with open(path, "w") as f:
                json.dump(doc, f)
            return path

        snap = write("snap.json", {
            "schema_version": 1, "experiment": "faults",
            "mode": "floors", "min_cells": 2, "survival_floor": 0.5,
            "rows": [],
        })
        good = write("good.json", {
            "schema_version": 1, "experiment": "faults", "rows": [
                campaign("none", 400, 0, 1.0),
                campaign("heavy", 300, 7, 0.8),
            ],
        })
        serve = write("serve.json", {
            "schema_version": 1, "experiment": "serve", "rows": [
                {
                    "row": "summary", "system": "Monarch(S=8)",
                    "load": 1.0, "modeled_fingerprint": "fp-none",
                },
            ],
        })
        expect("pass", *run("--faults", snap, good, serve),
               0, "faults OK")

        missing = os.path.join(td, "never_emitted.json")
        expect("missing-file", *run("--faults", snap, missing),
               1, "cannot read")

        truncated = os.path.join(td, "truncated.json")
        with open(truncated, "w") as f:
            f.write('{"schema_version": 1, "rows": [')
        expect("truncated-json", *run("--faults", snap, truncated),
               1, "not valid JSON")

        norows = write("norows.json",
                       {"schema_version": 1, "experiment": "faults"})
        expect("missing-rows", *run("--faults", snap, norows),
               1, "missing 'rows' list")

        dirty = write("dirty.json", {
            "schema_version": 1, "experiment": "faults", "rows": [
                campaign("none", 400, 3, 1.0),
                campaign("heavy", 300, 7, 0.8),
            ],
        })
        expect("dirty-zero-fault", *run("--faults", snap, dirty),
               1, "zero-fault campaign reports")

        rising = write("rising.json", {
            "schema_version": 1, "experiment": "faults", "rows": [
                campaign("none", 300, 0, 1.0),
                campaign("heavy", 900, 7, 0.8),
            ],
        })
        expect("hits-rose", *run("--faults", snap, rising),
               1, "hits rose")

        drifted = write("drifted_serve.json", {
            "schema_version": 1, "experiment": "serve", "rows": [
                {
                    "row": "summary", "system": "Monarch(S=8)",
                    "load": 1.0, "modeled_fingerprint": "fp-elsewhere",
                },
            ],
        })
        expect("fingerprint-drift", *run("--faults", snap, good, drifted),
               1, "changed the model")

    print("bench_regression: selftest OK (7 scenarios)")


def main(argv):
    if len(argv) >= 4 and argv[1] == "--service":
        check_service(argv[2], argv[3])
    elif len(argv) >= 4 and argv[1] == "--xamsearch":
        check_xamsearch(argv[2], argv[3])
    elif len(argv) >= 4 and argv[1] == "--memcache":
        check_memcache(argv[2], argv[3])
    elif len(argv) >= 3 and argv[1] == "--scaling":
        check_scaling(argv[2])
    elif len(argv) >= 2 and argv[1] == "--replay-check":
        check_replay(argv[2:])
    elif len(argv) >= 4 and argv[1] == "--faults":
        check_faults(argv[2], argv[3],
                     argv[4] if len(argv) > 4 else None)
    elif len(argv) >= 2 and argv[1] == "--selftest":
        selftest()
    else:
        fail(
            "usage: bench_regression.py --service SNAPSHOT FRESH | "
            "--xamsearch SNAPSHOT FRESH | --memcache SNAPSHOT FRESH | "
            "--scaling FRESH | --replay-check JSON JSON... | "
            "--faults SNAPSHOT FRESH [SERVE] | --selftest"
        )


if __name__ == "__main__":
    main(sys.argv)
